"""Partitioned indexes: the partition map + pruning + O(1) retention.

SNIPPETS Snippet 3's "Index Partitioning" pattern, grown onto the arena
machinery: a ``PartitionedTable`` groups per-partition ``IndexedTable``s
(or ``DistributedTable``s — partition-major, shard-minor) under ONE
``PartitionSpec`` describing range or list partitioning on a designated
column.  Each partition keeps its own capacity class, snapshot, and MVCC
machinery — ``create_index`` / ``_ingest_arrays`` / ``append`` are reused
unchanged per partition — while the spec lives as **treedef metadata**:

* routing never retraces (the spec is hashable, compared by value, and
  participates in the jit cache key exactly like ``Schema``);
* ``drop_partition`` is an O(1) *structural* removal — the surviving
  partitions' subtrees are untouched, so every jitted read site keyed on
  a survivor keeps its compile-cache entry (zero recompiles, gated by
  ``scripts/trace_gate.py gate_partition``);
* appends route host-side on the partition column and land ONLY in the
  receiving partitions — the other partitions' leaves are not even
  copied.

Read pruning is exact when the partition column IS the schema key (each
key's rows then live in exactly one partition, and per-partition
newest-first equals global newest-first): a point-lookup batch is routed
host-side, each touched partition probes the full-shape key vector with
non-members masked to the ``EMPTY_KEY`` guaranteed-miss sentinel (static
shapes — one trace per partition structure), and results merge by
validity.  Partitions the batch never touches run NOTHING — under the
distributed backend that means the routed/broadcast exchange is skipped
entirely for non-matching partitions.  Partitioning on a non-key column
still gives filter pruning (planner rule P2) and retention; keyed reads
on such a table are rejected with a clear error rather than silently
merging cross-partition match lists.

Invalid output lanes are ZEROED (the merge only writes valid matches);
the monolithic path leaves row-0 garbage there.  Comparisons therefore
mask by validity — see tests/test_partition.py.

Trace accounting (the ``QUEUE_TRACES`` pattern): every per-partition
jitted read site bumps ``PARTITION_TRACES`` at trace time and records
the (flavor, structure, shapes) fingerprint it *should* compile for in
``_SITE_USE`` — ``site_traces() == expected_site_traces()`` is the
zero-retrace proof the gate asserts across appends, drops, and
retention sweeps.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
import re
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import joins
from repro.core import table as table_mod
from repro.core.hashindex import EMPTY_KEY
from repro.core.schema import Schema

# Trace counters for the zero-retrace gate (scripts/trace_gate.py
# gate_partition) — bumped inside jitted site bodies, so they count
# TRACES, not calls.
PARTITION_TRACES = {"lookup": 0}

# Fingerprints of every (flavor, table structure, query shape) a site was
# driven with: the number of compiles that SHOULD exist.
_SITE_USE: set = set()

_EMPTY_NP = np.int64(np.asarray(EMPTY_KEY))

# Partition ids name checkpoint subdirectories — filesystem-safe only.
_ID_RE = re.compile(r"[A-Za-z0-9_-]+")


def site_traces() -> int:
    """Total per-partition read-site traces so far."""
    return PARTITION_TRACES["lookup"]


def expected_site_traces() -> int:
    """Distinct (flavor, structure, shape) combinations driven — compare
    with ``site_traces()``: equal means zero retraces.

    Both counters are PROCESS-GLOBAL: they aggregate every partitioned
    frame and engine in the process.  Consumers that want a per-window
    view (e.g. ``QueryEngine.retraces``) subtract a baseline, which is
    only exact when nothing else drives partitioned lookups meanwhile.
    """
    return len(_SITE_USE)


def reset_trace_accounting():
    """Drop the trace counters, the site-use fingerprints, AND the jitted
    site cache (which pins runtime objects via its keys).  For
    long-running serving processes that churn through many key-batch
    shapes or runtimes — the next lookup recompiles its site, so never
    call this inside a zero-retrace gate window."""
    PARTITION_TRACES["lookup"] = 0
    _SITE_USE.clear()
    _lookup_site.cache_clear()


# ---------------------------------------------------------------------------
# PartitionSpec — hashable treedef metadata
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PartitionSpec:
    """Range or list partitioning on ``column`` — hashable by value, so it
    rides as treedef metadata (like ``Schema``) and partition routing never
    retraces.

    * ``kind="range"``: ``ranges[i] = (lo, hi)`` — partition ``i`` owns
      values in ``[lo, hi)``.  Ranges are ascending and disjoint but need
      not be contiguous (drops leave holes; values in a hole are
      unmapped).
    * ``kind="list"``: ``values[i]`` — the explicit member set of
      partition ``i``.

    ``ids`` are stable human-readable partition names (``explain()`` and
    the retention API speak in them).  ``EMPTY_KEY`` (int64 min) is the
    reserved guaranteed-miss sentinel and is never mapped.
    """

    column: str
    kind: str                                     # "range" | "list"
    ranges: tuple = ()                            # ((lo, hi), ...) ascending
    values: tuple = ()                            # ((v, ...), ...) disjoint
    ids: tuple = ()

    def __post_init__(self):
        if self.kind not in ("range", "list"):
            raise ValueError(f"kind must be 'range' or 'list', "
                             f"got {self.kind!r}")
        n = self.num_partitions
        if n == 0:
            raise ValueError("a partition spec needs at least one partition")
        if len(self.ids) != n or len(set(self.ids)) != n:
            raise ValueError("ids must be unique, one per partition")
        for pid in self.ids:
            # ids name checkpoint subdirectories (save_partitioned) — keep
            # them filesystem-safe so user input can't escape the layout
            if not isinstance(pid, str) or not _ID_RE.fullmatch(pid):
                raise ValueError(
                    f"partition id {pid!r} invalid: ids must match "
                    f"[A-Za-z0-9_-]+ (they name checkpoint subdirs)")
        if self.kind == "range":
            for lo, hi in self.ranges:
                if not lo < hi:
                    raise ValueError(f"empty range [{lo}, {hi})")
            for (_, hi), (lo, _) in zip(self.ranges, self.ranges[1:]):
                if lo < hi:
                    raise ValueError("ranges must be ascending and disjoint")
        else:
            flat = [v for grp in self.values for v in grp]
            if len(set(flat)) != len(flat) or \
                    any(not grp for grp in self.values):
                raise ValueError("list partitions must be non-empty and "
                                 "disjoint")
            if int(_EMPTY_NP) in flat:
                raise ValueError("EMPTY_KEY is the reserved miss sentinel "
                                 "and cannot be a partition member")

    # -- constructors ---------------------------------------------------------

    @classmethod
    def range_(cls, column: str, cuts, ids=None) -> "PartitionSpec":
        """Contiguous range partitions from ascending cut points:
        ``cuts=[c0, c1, c2]`` -> partitions ``[c0,c1)``, ``[c1,c2)``."""
        cuts = [int(c) for c in cuts]
        if len(cuts) < 2 or cuts != sorted(set(cuts)):
            raise ValueError("cuts must be >= 2 strictly ascending values")
        ranges = tuple(zip(cuts, cuts[1:]))
        ids = (tuple(ids) if ids is not None
               else tuple(f"p{i}" for i in range(len(ranges))))
        return cls(column=column, kind="range", ranges=ranges, ids=ids)

    @classmethod
    def list_(cls, column: str, groups, ids=None) -> "PartitionSpec":
        """Explicit member-set partitions: ``groups=[(1, 2), (7,)]``."""
        vals = tuple(tuple(int(v) for v in g) for g in groups)
        ids = (tuple(ids) if ids is not None
               else tuple(f"p{i}" for i in range(len(vals))))
        return cls(column=column, kind="list", values=vals, ids=ids)

    # -- shape facts ----------------------------------------------------------

    @property
    def num_partitions(self) -> int:
        return len(self.ranges) if self.kind == "range" else len(self.values)

    def describe(self, i: int) -> str:
        if self.kind == "range":
            lo, hi = self.ranges[i]
            return f"{self.ids[i]}=[{lo},{hi})"
        return f"{self.ids[i]}={{{','.join(map(str, self.values[i]))}}}"

    def index_of(self, pid) -> int:
        """Partition index for an id (or a pass-through index)."""
        if isinstance(pid, str):
            try:
                return self.ids.index(pid)
            except ValueError:
                raise KeyError(f"no partition named {pid!r}; "
                               f"have {self.ids}") from None
        i = int(pid)
        if not 0 <= i < self.num_partitions:
            raise IndexError(f"partition {i} out of range "
                             f"[0, {self.num_partitions})")
        return i

    # -- routing (host-side, exact — mirrors the dist ingest router) ----------

    def route_host(self, vals) -> np.ndarray:
        """Owning partition index per value, ``-1`` = unmapped (including
        the ``EMPTY_KEY`` sentinel — pad lanes never touch a partition)."""
        v = np.asarray(vals).astype(np.int64).reshape(-1)
        out = np.full(v.shape, -1, np.int32)
        if self.kind == "range":
            los = np.array([r[0] for r in self.ranges], np.int64)
            his = np.array([r[1] for r in self.ranges], np.int64)
            i = np.searchsorted(los, v, side="right") - 1
            ok = (i >= 0) & (v < his[np.clip(i, 0, None)])
            out[ok] = i[ok]
        else:
            flat = np.array([x for g in self.values for x in g], np.int64)
            part = np.array([p for p, g in enumerate(self.values)
                             for _ in g], np.int32)
            order = np.argsort(flat)
            flat, part = flat[order], part[order]
            i = np.searchsorted(flat, v)
            # searchsorted returns len(flat) for values above the largest
            # member — clamp before indexing (a miss either way).
            j = np.minimum(i, flat.shape[0] - 1)
            ok = (i < flat.shape[0]) & (flat[j] == v)
            out[ok] = part[j[ok]]
        out[v == _EMPTY_NP] = -1
        return out

    def partition_of(self, value) -> int:
        return int(self.route_host(np.asarray([value]))[0])

    # -- pruning --------------------------------------------------------------

    def prune_eq(self, value) -> tuple:
        p = self.partition_of(value)
        return () if p < 0 else (p,)

    def prune_lt(self, value) -> tuple:
        """Partitions that can hold any row with ``column < value``."""
        value = int(value)
        if self.kind == "range":
            return tuple(i for i, (lo, _) in enumerate(self.ranges)
                         if lo < value)
        return tuple(i for i, g in enumerate(self.values)
                     if any(v < value for v in g))

    # -- retention ------------------------------------------------------------

    def drop(self, i: int) -> "PartitionSpec":
        i = self.index_of(i)
        if self.num_partitions == 1:
            raise ValueError("cannot drop the last partition")
        cut = lambda t: t[:i] + t[i + 1:]
        return dataclasses.replace(
            self, ids=cut(self.ids),
            ranges=cut(self.ranges) if self.kind == "range" else (),
            values=cut(self.values) if self.kind == "list" else ())


# ---------------------------------------------------------------------------
# PartitionedTable — the grouped pytree
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=["parts", "version"], meta_fields=["spec"])
@dataclasses.dataclass(frozen=True)
class PartitionedTable:
    """Per-partition tables under one spec and one global MVCC version.

    ``parts`` is a tuple of ``IndexedTable`` | ``DistributedTable`` — a
    pytree container, so each partition is its own subtree: appends into
    one partition leave every other partition's leaves untouched, and a
    ``drop_partition`` removes a subtree without perturbing the
    survivors (their per-partition jitted read sites keep their compile
    cache — the O(1) retention contract).  ``spec`` is treedef metadata;
    ``version`` is the global MVCC scalar (one bump per append / drop /
    retention sweep / compact)."""

    parts: tuple
    version: jax.Array
    spec: PartitionSpec

    @property
    def schema(self) -> Schema:
        return self.parts[0].schema

    @property
    def rows_per_batch(self) -> int:
        return self.parts[0].rows_per_batch

    @property
    def layout(self) -> str:
        return self.parts[0].layout

    @property
    def slots(self) -> int:
        return self.parts[0].slots

    @property
    def num_partitions(self) -> int:
        return len(self.parts)

    @property
    def partition_ids(self) -> tuple:
        return self.spec.ids

    @property
    def dist(self) -> bool:
        """True when partitions are shard-stacked (partition-major,
        shard-minor)."""
        return hasattr(self.parts[0], "num_shards")

    @property
    def shards_per_partition(self) -> int:
        return int(self.parts[0].num_shards) if self.dist else 1

    def num_rows(self):
        return sum(int(np.asarray(p.num_rows())) for p in self.parts)

    def index_nbytes(self, **kw) -> int:
        return sum(int(p.index_nbytes(**kw)) for p in self.parts)

    def data_nbytes(self, **kw) -> int:
        return sum(int(p.data_nbytes(**kw)) for p in self.parts)

    def with_flat_data(self) -> "PartitionedTable":
        if self.dist:
            return self
        return dataclasses.replace(
            self, parts=tuple(p.with_flat_data() for p in self.parts))

    def per_partition_bytes(self) -> list:
        """Logical vs reserved bytes per partition — arena slack in cold
        partitions is no longer attributed to the hot window
        (benchmarks/memory_overhead.py; data/store.py)."""
        out = []
        for i, p in enumerate(self.parts):
            out.append({
                "partition": self.spec.ids[i],
                "desc": self.spec.describe(i),
                "rows": int(np.asarray(p.num_rows())),
                "index_logical": int(p.index_nbytes(logical=True)),
                "index_reserved": int(p.index_nbytes()),
                "data_logical": int(p.data_nbytes(logical=True)),
                "data_reserved": int(p.data_nbytes()),
            })
        return out


# ---------------------------------------------------------------------------
# Construction + the write path (host routing, per-partition arenas)
# ---------------------------------------------------------------------------

def _dd():
    from repro.dist import dtable
    return dtable


def split_by_partition(spec: PartitionSpec, cols: dict, valid=None,
                       *, strict: bool = True) -> list:
    """Host-route a delta: ``[(partition_index, sub_cols, sub_valid), ...]``
    for the partitions that receive rows.  ``strict`` rejects valid rows
    whose partition-column value maps to no partition (the append
    contract — silently dropping rows is how data loss happens)."""
    pvals = np.asarray(cols[spec.column]).reshape(-1)
    n = pvals.shape[0]
    v = (np.ones(n, bool) if valid is None
         else np.asarray(valid, bool).reshape(-1))
    dest = spec.route_host(pvals)
    if strict:
        bad = v & (dest < 0)
        if bad.any():
            sample = np.unique(pvals[bad])[:8]
            raise ValueError(
                f"{int(bad.sum())} row(s) have partition-column "
                f"{spec.column!r} values outside every partition "
                f"(e.g. {sample.tolist()}); extend the spec or drop them")
    out = []
    for p in np.unique(dest[v & (dest >= 0)]):
        m = v & (dest == p)
        sub = {k: np.asarray(c)[m] for k, c in cols.items()}
        out.append((int(p), sub, None))
    return out


def _empty_part_cols(schema: Schema) -> tuple:
    """A one-row all-invalid placeholder: the cheapest buildable arena
    (``create_index`` wants >= 1 row; the row is never visible)."""
    cols = {c.name: np.zeros(1, np.dtype(c.dtype)) for c in schema.columns}
    return cols, np.zeros(1, bool)


def create_partitioned(cols: dict, schema: Schema, spec: PartitionSpec, *,
                       num_shards: int = 1, rt=None,
                       rows_per_batch: int = 4096, layout: str = "row",
                       slots: int | None = None, valid=None,
                       reserve: int | None = None,
                       track_hot: int | None = None,
                       hot_mode: str = "topk") -> PartitionedTable:
    """Route the creation columns by ``spec.column`` and build one arena
    per partition (every partition in the spec is built — empty ones get
    a placeholder arena so later appends land in an existing capacity
    class).  ``num_shards > 1`` builds each partition shard-stacked:
    partition-major, shard-minor."""
    if spec.column not in schema.names:
        raise ValueError(f"partition column {spec.column!r} not in schema "
                         f"{schema.names}")
    kw = {} if slots is None else {"slots": slots}
    routed = dict()
    for p, sub, _ in split_by_partition(spec, cols, valid):
        routed[p] = sub
    parts = []
    for p in range(spec.num_partitions):
        if p in routed:
            pc, pv = routed[p], None
        else:
            pc, pv = _empty_part_cols(schema)
        if num_shards == 1:
            t = table_mod.create_index(
                pc, schema, rows_per_batch=rows_per_batch, layout=layout,
                valid=pv, reserve=reserve, track_hot=track_hot,
                hot_mode=hot_mode, **kw)
        else:
            t = _dd().create_distributed(
                pc, schema, num_shards, rows_per_batch=rows_per_batch,
                layout=layout, valid=pv, reserve=reserve, rt=rt,
                track_hot=track_hot, hot_mode=hot_mode, **kw)
        parts.append(t)
    return PartitionedTable(parts=tuple(parts), spec=spec,
                            version=jnp.asarray(0, jnp.int32))


def append_partitioned(pt: PartitionedTable, cols: dict, valid=None, *,
                       rt=None, donate: bool = False,
                       compact_threshold: int | None = None
                       ) -> PartitionedTable:
    """MVCC append, routed: only the receiving partitions' arenas ingest
    (in-class appends there change no leaf shapes), every other partition
    is carried through BY REFERENCE — surviving read sites never retrace.
    One global version bump for the whole delta."""
    parts = list(pt.parts)
    for p, sub, sub_valid in split_by_partition(pt.spec, cols, valid):
        if pt.dist:
            parts[p] = _dd().append_distributed(
                parts[p], sub, sub_valid, rt=rt, donate=donate,
                compact_threshold=compact_threshold)
        else:
            parts[p] = table_mod.append(
                parts[p], sub, sub_valid, donate=donate,
                compact_threshold=compact_threshold)
    return dataclasses.replace(pt, parts=tuple(parts),
                               version=pt.version + 1)


def compact_partitioned(pt: PartitionedTable, *, rt=None,
                        reserve: int | None = None) -> PartitionedTable:
    parts = []
    for p in pt.parts:
        if pt.dist:
            parts.append(_dd().compact_distributed(p, rt=rt,
                                                   reserve=reserve))
        else:
            parts.append(table_mod.compact(p, reserve=reserve))
    return dataclasses.replace(pt, parts=tuple(parts),
                               version=pt.version + 1)


# ---------------------------------------------------------------------------
# Retention: O(1) drop + rolling retain
# ---------------------------------------------------------------------------

def drop_partition(pt: PartitionedTable, pid) -> PartitionedTable:
    """O(1) retention: remove one partition STRUCTURALLY — a treedef-meta
    change plus one version bump.  No data moves, nothing compacts, and
    the surviving partitions' subtrees are the SAME objects, so jitted
    read sites keyed on them keep their compile cache (gate_partition
    proves zero retraces)."""
    i = pt.spec.index_of(pid)
    return dataclasses.replace(
        pt, parts=pt.parts[:i] + pt.parts[i + 1:], spec=pt.spec.drop(i),
        version=pt.version + 1)


def retain(pt: PartitionedTable, *, min_value=None,
           keep=None) -> PartitionedTable:
    """Rolling retention sweep.  ``min_value`` (range specs): drop every
    partition wholly below it — the logs/events expiry the paper never
    reaches, O(#dropped) metadata work and zero device work.  ``keep``
    (any spec): the ids to survive.  One version bump for the sweep."""
    if (min_value is None) == (keep is None):
        raise ValueError("pass exactly one of min_value= or keep=")
    if min_value is not None:
        if pt.spec.kind != "range":
            raise ValueError("min_value retention needs a range spec; "
                             "use keep= for list specs")
        drop_ids = [pt.spec.ids[i]
                    for i, (_, hi) in enumerate(pt.spec.ranges)
                    if hi <= int(min_value)]
    else:
        keep = set(keep)
        unknown = keep - set(pt.spec.ids)
        if unknown:
            raise KeyError(f"unknown partition ids {sorted(unknown)}")
        drop_ids = [pid for pid in pt.spec.ids if pid not in keep]
    if len(drop_ids) == pt.num_partitions:
        raise ValueError("retention would drop every partition")
    if not drop_ids:
        return pt
    new = pt
    for pid in drop_ids:
        i = new.spec.index_of(pid)
        new = dataclasses.replace(
            new, parts=new.parts[:i] + new.parts[i + 1:],
            spec=new.spec.drop(i))
    return dataclasses.replace(new, version=pt.version + 1)


# ---------------------------------------------------------------------------
# Reads: pruned per-partition sites + validity merge
# ---------------------------------------------------------------------------

DEFAULT_ROUTED_THRESHOLD = 4096


def _check_keyed(pt: PartitionedTable, what: str):
    if pt.spec.column != pt.schema.key:
        raise ValueError(
            f"{what} on a partitioned frame needs the partition column to "
            f"BE the indexed key (partitioned on {pt.spec.column!r}, key "
            f"is {pt.schema.key!r}): a key's matches could otherwise span "
            f"partitions and the per-partition merge would reorder them. "
            f"Use filter() — planner rule P2 prunes scans on the "
            f"partition column — or partition on the key.")


def part_flavor(pt: PartitionedTable, num_queries: int, *,
                routed_threshold: int = DEFAULT_ROUTED_THRESHOLD) -> str:
    """The per-partition lookup flavor (the planner's L-rules applied
    inside each partition): local fused probe, or broadcast vs routed
    across the partition's shards."""
    if not pt.dist:
        return "local"
    return ("routed" if num_queries >= routed_threshold else "bcast")


@functools.lru_cache(maxsize=64)
def _lookup_site(flavor: str, max_matches: int, names, rt):
    """ONE jitted read site per (flavor, max_matches, names, runtime) —
    shared by every partition whose structure matches (jit adds the
    structure/shape dimension to the cache key).  The body bumps
    PARTITION_TRACES at trace time: the gate's retrace counter.

    Bounded: the cache keys pin ``rt`` (and the jit caches behind the
    functions), so an unbounded cache is a slow leak in serving
    processes that churn runtimes.  64 is far above any gate/bench
    working set; an eviction costs one recompile (counted as a retrace),
    not correctness.  ``reset_trace_accounting()`` clears it outright."""
    if flavor == "local":
        def f(part, keys):
            PARTITION_TRACES["lookup"] += 1
            return joins.indexed_lookup(part, keys,
                                        max_matches=max_matches, names=names)
    elif flavor == "bcast":
        def f(part, keys):
            PARTITION_TRACES["lookup"] += 1
            cols, valid, _ = _dd().lookup(part, keys,
                                          max_matches=max_matches,
                                          names=names, rt=rt)
            return cols, valid
    elif flavor == "routed":
        def f(part, keys):
            PARTITION_TRACES["lookup"] += 1
            return _dd().lookup_routed_flat(part, keys,
                                           max_matches=max_matches,
                                           names=names, rt=rt)
    else:
        raise ValueError(f"unknown partition lookup flavor {flavor!r}")
    return jax.jit(f)


def _fingerprint(part, keys_shape, flavor, max_matches, names, rt):
    leaves = jax.tree_util.tree_leaves(part)
    shapes = tuple((tuple(np.shape(l)), str(np.asarray(l).dtype)
                    if not isinstance(l, jax.Array) else str(l.dtype))
                   for l in leaves)
    return (flavor, max_matches, names, rt,
            jax.tree_util.tree_structure(part), shapes, tuple(keys_shape))


def _out_names(pt: PartitionedTable, names) -> tuple:
    return tuple(names) if names is not None else pt.schema.names


def _raw_lookup(flavor, part, keys, max_matches, names, rt):
    """The un-jitted per-partition lookup (the scan-all tracer path runs
    inside the CALLER's trace, so no site cache applies)."""
    if flavor == "local":
        return joins.indexed_lookup(part, keys, max_matches=max_matches,
                                    names=names)
    if flavor == "bcast":
        cols, valid, _ = _dd().lookup(part, keys, max_matches=max_matches,
                                      names=names, rt=rt)
        return cols, valid
    return _dd().lookup_routed_flat(part, keys, max_matches=max_matches,
                                    names=names, rt=rt)


def lookup_partitioned(pt: PartitionedTable, keys, *, max_matches: int,
                       names=None, rt=None,
                       routed_threshold: int = DEFAULT_ROUTED_THRESHOLD):
    """Pruned point lookup: rows for each key, newest-first, bit-identical
    (on valid lanes) to the monolithic frame.

    Host-concrete keys route on the partition spec; each TOUCHED
    partition probes the full-shape batch with non-members masked to the
    guaranteed-miss sentinel (static shapes, one compile per partition
    structure) and the [Q, M] results merge by validity — disjoint by
    construction because the partition column is the key.  Partitions no
    key maps to are never probed: under ``dist`` their exchange is
    skipped entirely.  Tracer keys (the caller is inside jit) fall back
    to scanning every partition in-trace — correct, unpruned.
    """
    joins.check_max_matches(max_matches)
    _check_keyed(pt, "lookup")
    keys_j = joins.as_int64_keys(keys)
    names_t = None if names is None else tuple(names)
    sel = _out_names(pt, names_t)
    q = int(keys_j.shape[0])
    flavor = part_flavor(pt, q, routed_threshold=routed_threshold)

    if isinstance(keys_j, jax.core.Tracer):
        out_cols = {n: jnp.zeros((q, max_matches),
                                 pt.schema.column(n).jnp_dtype) for n in sel}
        out_valid = jnp.zeros((q, max_matches), bool)
        for part in pt.parts:
            c, v = _raw_lookup(flavor, part, keys_j, max_matches, names_t,
                               rt)
            out_valid = out_valid | v
            out_cols = {n: jnp.where(v, c[n], out_cols[n]) for n in sel}
        return out_cols, out_valid

    keys_np = np.asarray(keys_j)
    dest = pt.spec.route_host(keys_np)
    touched = [int(p) for p in np.unique(dest[dest >= 0])]
    out_cols = {n: jnp.zeros((q, max_matches),
                             pt.schema.column(n).jnp_dtype) for n in sel}
    out_valid = jnp.zeros((q, max_matches), bool)
    fn = _lookup_site(flavor, max_matches, names_t, rt)
    for p in touched:
        masked = np.where(dest == p, keys_np, _EMPTY_NP)
        _SITE_USE.add(_fingerprint(pt.parts[p], masked.shape, flavor,
                                   max_matches, names_t, rt))
        c, v = fn(pt.parts[p], jnp.asarray(masked))
        out_valid = out_valid | v
        out_cols = {n: jnp.where(v, c[n], out_cols[n]) for n in sel}
    return out_cols, out_valid


def join_partitioned(pt: PartitionedTable, probe_cols: dict, on: str, *,
                     max_matches: int, names=None, rt=None,
                     routed_threshold: int = DEFAULT_ROUTED_THRESHOLD):
    """Pruned equi-join, ``pt`` as build side: per-partition local joins —
    each probe row's key owns exactly one partition, so there is no
    cross-partition exchange at all (planner rule P3); partitions no
    probe key maps to run nothing.  Output contract matches
    ``joins.indexed_join``: (build_cols [Q, M], probe broadcast [Q, M],
    valid [Q, M]) in probe order.  ``on`` names the PROBE column (the
    ``indexed_join`` contract) — the build side always joins on its
    indexed key, which ``_check_keyed`` requires to be the partition
    column."""
    if on not in probe_cols:
        raise ValueError(f"probe column {on!r} not in probe_cols "
                         f"{sorted(probe_cols)}")
    _check_keyed(pt, "join")
    keys = joins.as_int64_keys(probe_cols[on])
    bc, valid = lookup_partitioned(pt, keys, max_matches=max_matches,
                                   names=names, rt=rt,
                                   routed_threshold=routed_threshold)
    m = valid.shape[1]
    probe_b = {k: jnp.broadcast_to(jnp.asarray(v)[:, None],
                                   (jnp.shape(v)[0], m))
               for k, v in probe_cols.items()}
    return bc, probe_b, valid


def collect_partitions(pt: PartitionedTable, kept=None, *, rt=None):
    """Materialize (cols, valid) across ``kept`` partition indices (all
    when None) — the pruned-scan executor behind planner rule P2."""
    kept = range(pt.num_partitions) if kept is None else kept
    cols = {n: [] for n in pt.schema.names}
    valid = []
    for i in kept:
        part = pt.parts[i]
        if pt.dist:
            c = _dd().collect_cols(part, rt=rt)
            n = np.shape(next(iter(c.values())))[0]
            v = np.ones(n, bool)
            for name in pt.schema.names:
                cols[name].append(np.asarray(c[name]))
            valid.append(v)
        else:
            v = None
            for name in pt.schema.names:
                vals, pv = part.scan_column(name)
                cols[name].append(np.asarray(vals))
                v = np.asarray(pv)
            valid.append(v)
    if not valid:
        return ({n: jnp.zeros(0, pt.schema.column(n).jnp_dtype)
                 for n in pt.schema.names}, jnp.zeros(0, bool))
    return ({n: jnp.asarray(np.concatenate(cols[n]))
             for n in pt.schema.names},
            jnp.asarray(np.concatenate(valid)))


# ---------------------------------------------------------------------------
# Persistence + elasticity (per-partition checkpoint subdirs)
# ---------------------------------------------------------------------------

def _ckpt():
    from repro.dist import checkpoint
    return checkpoint


def spec_to_dict(spec: PartitionSpec) -> dict:
    return {"column": spec.column, "kind": spec.kind,
            "ranges": [list(r) for r in spec.ranges],
            "values": [list(g) for g in spec.values],
            "ids": list(spec.ids)}


def spec_from_dict(d: dict) -> PartitionSpec:
    return PartitionSpec(column=d["column"], kind=d["kind"],
                         ranges=tuple(tuple(r) for r in d["ranges"]),
                         values=tuple(tuple(g) for g in d["values"]),
                         ids=tuple(d["ids"]))


def save_partitioned(path: str, pt: PartitionedTable):
    """Checkpoint: one subdir per partition (CRC-verified leaf format)
    plus the spec + global version as JSON meta."""
    os.makedirs(path, exist_ok=True)
    meta = {"spec": spec_to_dict(pt.spec), "dist": pt.dist,
            "version": int(np.asarray(pt.version))}
    with open(os.path.join(path, "partitions.json"), "w") as f:
        json.dump(meta, f)
    for i, part in enumerate(pt.parts):
        sub = os.path.join(path, f"part_{pt.spec.ids[i]}")
        if pt.dist:
            _ckpt().save_dtable(sub, part)
        else:
            _ckpt().save_table(sub, part)


def restore_partitioned(path: str, like: PartitionedTable
                        ) -> PartitionedTable:
    """Restore into ``like``'s structure (``like`` supplies treedefs and
    the runtime, per-partition)."""
    with open(os.path.join(path, "partitions.json")) as f:
        meta = json.load(f)
    spec = spec_from_dict(meta["spec"])
    if spec != like.spec:
        raise ValueError(f"checkpoint spec {spec} != like.spec {like.spec}")
    parts = []
    for i, part in enumerate(like.parts):
        sub = os.path.join(path, f"part_{spec.ids[i]}")
        if like.dist:
            parts.append(_ckpt().restore_dtable(sub, part))
        else:
            parts.append(_ckpt().restore_table(sub, part))
    return dataclasses.replace(
        like, parts=tuple(parts),
        version=jnp.asarray(meta["version"], jnp.int32))
