"""Key hashing for the Indexed DataFrame.

Two hash tiers, mirroring the paper:

* **partition hash** — routes a key to its owning shard (paper §III-C
  "hash partitioning scheme").  Must agree across every device, and must be
  *independent* of the bucket hash so shard-local bucket occupancy stays
  uniform after partitioning.
* **bucket hash** — places a key in a bucket of the shard-local dense index
  (our cTrie replacement).

Both are Fibonacci/splitmix-style multiplicative mixes: one int multiply +
shift/xor, fully vectorizable on the TPU VPU.  Keys are int64 at the API
boundary (strings are pre-hashed to int64 on the host at ingest — the paper
hashes strings to 32-bit for the cTrie; we keep 64 bits to cut collisions).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

# splitmix64 / Fibonacci constants.
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)
_GOLDEN = np.uint64(0x9E3779B97F4A7C15)


def _splitmix64(x):
    x = jnp.asarray(x).astype(jnp.uint64)
    x = (x ^ (x >> 30)) * _MIX1
    x = (x ^ (x >> 27)) * _MIX2
    return x ^ (x >> 31)


def bucket_hash(keys, num_buckets: int):
    """Bucket id in [0, num_buckets); num_buckets must be a power of two."""
    assert num_buckets & (num_buckets - 1) == 0, "num_buckets must be 2**k"
    h = _splitmix64(keys)
    # Take the *high* bits of the golden-ratio product: low bits correlate
    # with the partition hash's modulus for small shard counts.
    h = h * _GOLDEN
    shift = np.uint64(64 - int(num_buckets).bit_length() + 1)
    return (h >> shift).astype(jnp.int32) & jnp.int32(num_buckets - 1)


def partition_hash(keys, num_shards: int):
    """Owning shard id in [0, num_shards) for routing (any shard count)."""
    h = _splitmix64(jnp.asarray(keys).astype(jnp.uint64) ^ _GOLDEN)
    return (h % np.uint64(num_shards)).astype(jnp.int32)


def sketch_hash(keys, row: int, width: int):
    """Count-min-sketch column in [0, width) for plane ``row``.

    Per-row salts keep the planes independent of each other AND of the
    partition/bucket hashes (a hot key must not systematically collide
    with the same victims in every plane, and sketch occupancy must not
    correlate with shard ownership).  ``width`` must be a power of two.
    """
    assert width & (width - 1) == 0, "sketch width must be 2**k"
    salt = np.uint64((int(_GOLDEN) * (2 * int(row) + 3))
                     & 0xFFFFFFFFFFFFFFFF)
    h = _splitmix64(jnp.asarray(keys).astype(jnp.uint64) ^ salt)
    shift = np.uint64(64 - int(width).bit_length() + 1)
    return ((h * _GOLDEN) >> shift).astype(jnp.int32) & jnp.int32(width - 1)


def partition_hash_host(keys, num_shards: int) -> np.ndarray:
    """Pure-numpy ``partition_hash`` — bit-identical to the device version.

    The ingest router (dist/dtable._route_host) and any external
    coordinator must place rows on exactly the shard the device-side
    query routing will probe; a single disagreeing bit silently loses
    rows.  This mirror keeps the host path off the device (no transfer
    per routed batch) and tests/test_mesh_parity.py sweeps the agreement
    over adversarial keys.
    """
    x = np.asarray(keys).astype(np.uint64) ^ _GOLDEN
    with np.errstate(over="ignore"):
        x = (x ^ (x >> np.uint64(30))) * _MIX1
        x = (x ^ (x >> np.uint64(27))) * _MIX2
        x = x ^ (x >> np.uint64(31))
    return (x % np.uint64(num_shards)).astype(np.int32)


def split64(x):
    """int64 array -> (hi, lo) int32 planes.

    The TPU VPU has no 64-bit lanes (DESIGN.md §7); kernels and the Snapshot
    carry keys as two int32 planes and equality is two compares AND'd.
    """
    bits = jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.int64), jnp.uint64)
    lo = jax.lax.bitcast_convert_type(
        (bits & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32), jnp.int32)
    hi = jax.lax.bitcast_convert_type(
        (bits >> jnp.uint64(32)).astype(jnp.uint32), jnp.int32)
    return hi, lo


_FNV_OFFSET = np.uint64(0xCBF29CE484222325)
_FNV_PRIME = np.uint64(0x100000001B3)


def hash_string_host(s: str) -> int:
    """Host-side FNV-1a of a string key → int64 (ingest path for string
    columns; see DESIGN.md §9)."""
    h = np.uint64(0xCBF29CE484222325)
    for b in s.encode("utf-8"):
        h = np.uint64((int(h) ^ b) * 0x100000001B3 & 0xFFFFFFFFFFFFFFFF)
    return int(np.int64(h.astype(np.int64)))


def hash_strings_host(strings) -> np.ndarray:
    """Vectorized ``hash_string_host`` over a batch → int64 array.

    Bit-identical to the scalar loop by construction (and by
    tests/test_queue.py property test): the byte matrix walk applies the
    same FNV-1a step per position, masked so each string stops at its own
    byte length.  One ``np.char.encode`` + ``maxlen`` vectorized rounds
    replaces N Python loops — the paper's Fig-15 string-ingest tax, first
    cut (ROADMAP flights item).

    NUL caveat: numpy's S dtype cannot represent trailing ``\\x00`` bytes,
    so strings containing NUL fall back to the scalar path.
    """
    arr = np.asarray(strings, dtype=object).reshape(-1)
    n = arr.shape[0]
    if n == 0:
        return np.empty((0,), np.int64)
    blist = [s.encode("utf-8") for s in arr]
    lens = np.array([len(b) for b in blist], dtype=np.int64)
    # S-dtype storage silently strips *trailing* NULs (interior ones are
    # fine — the width is fixed) — those few strings go scalar.
    nul = np.array([b.endswith(b"\x00") for b in blist])
    out = np.full((n,), _FNV_OFFSET, np.uint64)
    maxlen = int(lens.max())
    if maxlen:
        mat = (np.array(blist, dtype=f"S{maxlen}")
               .view(np.uint8).reshape(n, maxlen).astype(np.uint64))
        with np.errstate(over="ignore"):
            for j in range(maxlen):
                live = j < lens
                step = (out ^ mat[:, j]) * _FNV_PRIME
                out = np.where(live, step, out)
    if nul.any():
        out[nul] = [np.uint64(hash_string_host(s) & 0xFFFFFFFFFFFFFFFF)
                    for s in arr[nul]]
    return out.astype(np.int64)


class StringDictionary:
    """Dictionary-encode cache over ``hash_strings_host`` (DESIGN.md §16).

    Streaming string ingest re-hashes the same small vocabulary every
    batch (carrier codes, airports, date strings — the paper's Fig-15
    string tax is mostly redundant work).  This cache keeps the
    vocabulary -> int64 code table across batches: each ``encode`` call
    uniques the batch (one ``np.unique``), FNV-hashes only the uniques
    never seen before, and scatters codes back through the inverse index
    — repeated strings never touch the byte-matrix hash again.

    Codes are exactly ``hash_strings_host``'s (bit-identical ingest
    whether or not a dictionary is used); ``decode`` keeps the reverse
    map for result rendering.  ``reused``/``hashed`` count rows for the
    before/after cell in BENCH_workloads.json.
    """

    def __init__(self):
        self._codes: dict = {}     # str -> int64 code
        self._strings: dict = {}   # int64 code -> str (reverse map)
        self.hashed = 0            # rows that paid the FNV byte walk
        self.reused = 0            # rows answered from the table

    def __len__(self) -> int:
        return len(self._codes)

    def encode(self, strings) -> np.ndarray:
        """Batch of strings -> int64 key codes, hashing only novel
        vocabulary.

        Fast path is a straight dict probe per row (C-level string hash,
        no sort): only rows that MISS fall back to ``np.unique`` + the
        FNV byte walk.  On a warm vocabulary every row takes the probe
        path, which also beats re-running the vectorized byte walk —
        that is the whole point of the cache.
        """
        arr = np.asarray(strings, dtype=object).reshape(-1)
        n = arr.shape[0]
        if n == 0:
            return np.empty((0,), np.int64)
        get = self._codes.get
        out = [get(s) for s in arr]
        miss = [i for i, c in enumerate(out) if c is None]
        if miss:
            uniq = np.unique(arr[miss])
            for s, h in zip(uniq, hash_strings_host(uniq)):
                self._codes[s] = np.int64(h)
                self._strings[int(h)] = s
            self.hashed += len(uniq)       # strings that paid the byte walk
            for i in miss:
                out[i] = self._codes[arr[i]]
        self.reused += n - len(miss)       # rows answered from the table
        return np.asarray(out, np.int64)

    def decode(self, codes) -> list:
        """int64 codes -> the original strings (None for unknown codes)."""
        return [self._strings.get(int(c)) for c in np.asarray(codes)]
