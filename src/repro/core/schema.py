"""Fixed-width table schemas + row-wise / columnar storage codecs.

The paper stores rows in binary *unsafe* buffers (row-wise, §III-C footnote:
"could seamlessly be changed to columnar formats").  We support both layouts:

* ``row``      — each row is ``width_words`` 4-byte words in one int32 array;
                 int64/float64 take two words, float32 is bitcast.  This is
                 the paper-faithful default and reproduces its Fig 8 finding
                 (projections pay for touching full rows).
* ``columnar`` — one typed array per column (the footnote's alternative),
                 used by the benchmarks to quantify that trade-off.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_DTYPES = {
    "int32": (jnp.int32, 1),
    "int64": (jnp.int64, 2),
    "float32": (jnp.float32, 1),
    "float64": (jnp.float64, 2),
}


@dataclasses.dataclass(frozen=True)
class Column:
    name: str
    dtype: str  # key in _DTYPES

    @property
    def jnp_dtype(self):
        return _DTYPES[self.dtype][0]

    @property
    def width_words(self) -> int:
        return _DTYPES[self.dtype][1]


@dataclasses.dataclass(frozen=True)
class Schema:
    """Ordered fixed-width columns; ``key`` names the indexed column."""

    columns: tuple[Column, ...]
    key: str

    def __post_init__(self):
        names = [c.name for c in self.columns]
        assert len(set(names)) == len(names), "duplicate column names"
        assert self.key in names, f"key column {self.key!r} not in schema"

    @staticmethod
    def of(key: str, **cols: str) -> "Schema":
        return Schema(tuple(Column(n, d) for n, d in cols.items()), key)

    @property
    def width_words(self) -> int:
        return sum(c.width_words for c in self.columns)

    @property
    def names(self):
        return tuple(c.name for c in self.columns)

    def column(self, name: str) -> Column:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(name)

    def offset_words(self, name: str) -> int:
        off = 0
        for c in self.columns:
            if c.name == name:
                return off
            off += c.width_words
        raise KeyError(name)

    def row_bytes(self) -> int:
        return self.width_words * 4

    # -- codecs --------------------------------------------------------------

    def encode_rows(self, cols: dict) -> jnp.ndarray:
        """dict[name -> [N] typed array] -> [N, width_words] int32."""
        parts = []
        n = None
        for c in self.columns:
            a = jnp.asarray(cols[c.name], c.jnp_dtype)
            n = a.shape[0] if n is None else n
            assert a.shape == (n,), f"column {c.name}: bad shape {a.shape}"
            parts.append(_to_words(a))
        return jnp.concatenate(parts, axis=1)

    def decode_rows(self, words, names=None) -> dict:
        """[..., width_words] int32 -> dict[name -> [...] typed array]."""
        names = names or self.names
        out = {}
        for name in names:
            c = self.column(name)
            off = self.offset_words(name)
            out[name] = _from_words(words[..., off:off + c.width_words],
                                    c.jnp_dtype)
        return out

    def key_from_words(self, words):
        return self.decode_rows(words, names=(self.key,))[self.key]


def _to_words(a) -> jnp.ndarray:
    """[N] typed -> [N, w] int32 words (little-endian word order)."""
    if a.dtype in (jnp.int32,):
        return a[:, None]
    if a.dtype == jnp.float32:
        return _bitcast32(a)[:, None]
    if a.dtype in (jnp.int64, jnp.float64):
        bits = _bitcast64(a)
        lo = (bits & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32)
        hi = (bits >> jnp.uint64(32)).astype(jnp.uint32)
        return jnp.stack([_bitcast32u(lo), _bitcast32u(hi)], axis=1)
    raise TypeError(f"unsupported dtype {a.dtype}")


def _from_words(w, dtype) -> jnp.ndarray:
    if dtype == jnp.int32:
        return w[..., 0]
    if dtype == jnp.float32:
        return _bitcast_to(w[..., 0], jnp.float32)
    lo = _bitcast_to(w[..., 0], jnp.uint32).astype(jnp.uint64)
    hi = _bitcast_to(w[..., 1], jnp.uint32).astype(jnp.uint64)
    bits = (hi << jnp.uint64(32)) | lo
    if dtype == jnp.int64:
        return _bitcast_to(bits, jnp.int64)
    return _bitcast_to(bits, jnp.float64)


def _bitcast32(a):
    return jax.lax.bitcast_convert_type(a, jnp.int32)


def _bitcast32u(a):
    return jax.lax.bitcast_convert_type(a, jnp.int32)


def _bitcast64(a):
    return jax.lax.bitcast_convert_type(a, jnp.uint64)


def _bitcast_to(a, dtype):
    return jax.lax.bitcast_convert_type(a, dtype)
