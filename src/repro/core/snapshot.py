"""Snapshot — the read-optimized *stored* form of an IndexedTable.

DESIGN.md §3: the paper's core claim (Fig 1, §III-C) is that the index is
built once and probed millions of times, so the probe path must not scale
with the number of MVCC append segments.  The fused probe -> chain-walk ->
gather pipeline therefore runs over a flat multi-segment view:

* per-segment ``FlatBlock``s — each delta index's bucket planes with int64
  keys pre-split into (hi, lo) int32 (DESIGN.md §7), kept **ragged** at the
  segment's own bucket count (bucket ids are computed modulo each segment's
  ``num_buckets``, carried as ``bucket_counts`` meta — nothing is padded);
* ``prev [capacity] int32`` — the segments' backward-pointer arrays
  concatenated in global row order, so a chain walk is one gather per step;
* ``data`` — *optional* contiguous row storage (``[capacity, W]`` int32
  words or per-column flat arrays) for single-gather row decode.  ``None``
  until a version actually decodes rows: the probe path never touches row
  data, so append-heavy workloads don't pay an O(capacity) copy per
  version.
* ``fill [scalar] int32`` — the first *unwritten* global row id
  (DESIGN.md §4): segments are capacity-reserved arenas, so lanes in
  ``[fill, capacity)`` of the tail are reserved-but-unwritten slack.  The
  fused probe/chain-walk/gather paths mask every emitted row id by
  ``fill`` — with buffer donation a reserved lane may alias retired
  memory, and masking guarantees it can never decode.  ``fill`` is a
  data leaf (not treedef meta): arena appends bump it on-device with
  zero pytree shape change, which is what keeps every jitted read site
  compile-cached across appends.

A Snapshot is a **registered pytree** and lives on the table as a stored
field (``IndexedTable.snapshot``), not a host-side cache: jitted functions
that take the table as a pytree *argument* trace the snapshot's arrays as
leaves instead of rebuilding the view in-graph per call, and the
distributed layer (repro/dist) stacks snapshots across a leading shard
axis and vmaps the same lookup code per shard.  ``bucket_counts`` and
``layout`` ride in the treedef, so structurally equal tables hit the same
jit cache entry.

Construction rules (there is no invalidation — a Snapshot is a pure
function of the immutable segments tuple):

1. ``create_index`` builds the probe side eagerly (``snapshot_from_
   segments``) — O(index size) split/concat, shares every buffer.
2. ``append`` extends the parent's snapshot (``extend_snapshot``): only
   the delta segment's block is computed; parent blocks are reused by
   reference (a regression test asserts identity).  Flat data is carried
   forward only if the parent had materialized it.
3. ``compact`` starts from a fresh single-segment snapshot.
4. Old versions keep their old snapshots — MVCC divergence (paper
   Listing 2) needs no copy-on-write.

``BLOCK_BUILDS`` / ``DATA_BUILDS`` count construction work; the tracing
regression tests assert they do not move while a jitted lookup traces or
runs with the table as an argument (zero in-graph rebuilds).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import hashing

# Construction counters (test instrumentation): bumped once per FlatBlock /
# flat-data build.  Host-side eager builds (create/append) bump them; a
# jitted lookup taking the table as a pytree argument must not.
BLOCK_BUILDS = 0
DATA_BUILDS = 0


@partial(jax.tree_util.register_dataclass,
         data_fields=["key_hi", "key_lo", "ptrs"],
         meta_fields=["num_buckets"])
@dataclasses.dataclass(frozen=True)
class FlatBlock:
    """One segment's probe-side contribution to a Snapshot.

    Blocks are immutable and shared by reference across table versions:
    ``extend_snapshot`` appends one new block (the delta) and never
    recomputes a parent block.  Planes stay ragged (each segment's own
    bucket count) so per-delta cost is O(delta index size).
    """

    key_hi: jax.Array     # [nb, slots] int32 — bucket keys, high plane
    key_lo: jax.Array     # [nb, slots] int32 — bucket keys, low plane
    ptrs: jax.Array       # [nb, slots] int32 — head ptrs (GLOBAL row ids)
    num_buckets: int


@partial(jax.tree_util.register_dataclass,
         data_fields=["blocks", "prev", "data", "fill"],
         meta_fields=["bucket_counts", "layout"])
@dataclasses.dataclass(frozen=True)
class Snapshot:
    """Flat multi-segment view of one table version — a stored pytree."""

    blocks: tuple[FlatBlock, ...]
    prev: jax.Array                 # [capacity] int32, global row order
    data: object                    # None | [cap, W] int32 | dict[name->[cap]]
    fill: jax.Array                 # scalar int32 — first unwritten row id
    bucket_counts: tuple[int, ...]  # per-segment bucket counts (ragged)
    layout: str

    @property
    def capacity(self) -> int:
        return self.prev.shape[-1]

    @property
    def num_segments(self) -> int:
        return len(self.blocks)

    @property
    def key_planes(self):
        """Per-segment (hi, lo, ptrs) triples, oldest -> newest."""
        return tuple((b.key_hi, b.key_lo, b.ptrs) for b in self.blocks)

    def nbytes(self) -> int:
        """Memory the snapshot holds beyond the segments' own arrays."""
        n = sum((b.key_hi.size + b.key_lo.size + b.ptrs.size) * 4
                for b in self.blocks) + self.prev.size * 4
        if self.data is None:
            return n
        if self.layout == "row":
            return n + self.data.size * 4
        return n + sum(a.size * a.dtype.itemsize for a in self.data.values())


def probe_view(blocks, prev, fill, *, bucket_counts, layout) -> Snapshot:
    """A probe-side-only Snapshot over explicit planes (``data=None``).

    Used *inside* the fused ingest/flush jits (``table._ingest_arrays`` /
    ``table._flush_core``) to probe the PRE-write table state for parent
    head links, and by readers that only need the probe pipeline.  The
    hard-mask contract lives here: every fused path masks emitted row ids
    by ``fill``, so a row id at or past ``fill`` NEVER decodes.  That one
    invariant is what keeps two kinds of not-yet-data invisible —
    reserved-but-unwritten arena slack (which, under donation, may alias
    retired buffers), and rows sitting in an ``AppendQueue`` ring
    (DESIGN.md §13): queued deltas live *beside* the arena and only move
    ``fill`` at flush, so MVCC snapshot isolation holds with no reader
    changes — unflushed lanes are invisible by construction.
    """
    return Snapshot(blocks=tuple(blocks), prev=prev, data=None, fill=fill,
                    bucket_counts=tuple(bucket_counts), layout=layout)


def block_from_segment(seg) -> FlatBlock:
    """Split one segment's delta index into a probe-side block."""
    global BLOCK_BUILDS
    BLOCK_BUILDS += 1
    hi, lo = hashing.split64(seg.index.bucket_keys)
    return FlatBlock(key_hi=hi, key_lo=lo, ptrs=seg.index.bucket_ptrs,
                     num_buckets=seg.index.num_buckets)


def flat_data_from_segments(segments, schema, layout):
    """Contiguous data for single-gather row decode (the optional side)."""
    global DATA_BUILDS
    DATA_BUILDS += 1
    if layout == "row":
        w = schema.width_words
        if len(segments) == 1:
            return segments[0].data.reshape(segments[0].capacity, w)
        return jnp.concatenate([s.data.reshape(s.capacity, w)
                                for s in segments], axis=0)
    if len(segments) == 1:
        return {c.name: segments[0].data[c.name].reshape(-1)
                for c in schema.columns}
    return {c.name: jnp.concatenate([s.data[c.name].reshape(-1)
                                     for s in segments])
            for c in schema.columns}


def fill_after(seg) -> jax.Array:
    """First unwritten row id given a tail segment: one past its last
    valid lane (its ``row_base`` when the segment is all-padding).  Arena
    tails keep valid lanes left-packed, so this is exactly
    ``row_base + valid_count``; for legacy interleaved-padding segments it
    is the safe upper bound (interior padding stays addressable and
    decodes zeros, same as before)."""
    v = seg.valid
    cap = v.shape[-1]
    last = cap - jnp.argmax(v[::-1]).astype(jnp.int32)
    return jnp.asarray(seg.row_base, jnp.int32) + jnp.where(
        jnp.any(v), last.astype(jnp.int32), jnp.int32(0))


def snapshot_from_segments(segments, layout, *, schema=None,
                           with_data: bool = False) -> Snapshot:
    """Build a Snapshot from scratch (create_index / compact path)."""
    blocks = tuple(block_from_segment(s) for s in segments)
    prev = (segments[0].prev if len(segments) == 1
            else jnp.concatenate([s.prev for s in segments]))
    data = (flat_data_from_segments(segments, schema, layout)
            if with_data else None)
    return Snapshot(blocks=blocks, prev=prev, data=data,
                    fill=fill_after(segments[-1]),
                    bucket_counts=tuple(b.num_buckets for b in blocks),
                    layout=layout)


def extend_snapshot(snap: Snapshot, seg, *, schema) -> Snapshot:
    """Parent snapshot + one delta segment -> child snapshot.

    O(delta index) block build plus one ``prev`` concat (4 B/row); parent
    blocks are reused by reference.  Flat data is extended only when the
    parent had materialized it, so append-heavy versions that never decode
    stay O(delta).
    """
    block = block_from_segment(seg)
    prev = jnp.concatenate([snap.prev, seg.prev], axis=-1)
    if snap.data is None:
        data = None
    elif snap.layout == "row":
        w = schema.width_words
        data = jnp.concatenate(
            [snap.data, seg.data.reshape(seg.capacity, w)], axis=0)
    else:
        data = {c.name: jnp.concatenate(
                    [snap.data[c.name], seg.data[c.name].reshape(-1)])
                for c in schema.columns}
    return Snapshot(blocks=snap.blocks + (block,), prev=prev, data=data,
                    fill=fill_after(seg),
                    bucket_counts=snap.bucket_counts + (block.num_buckets,),
                    layout=snap.layout)


def strip_data(snap: Snapshot) -> Snapshot:
    """Probe-side-only view: keeps lookup jit caches independent of whether
    (and when) a table materialized its flat data."""
    if snap.data is None:
        return snap
    return dataclasses.replace(snap, data=None)
