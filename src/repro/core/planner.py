"""Query planner — the Catalyst-integration analog (paper §III-B, Fig 2).

The paper hooks Spark's Catalyst with *optimization rules* that rewrite
logical operators into indexed physical operators whenever an equality
predicate or equi-join touches an indexed column, falling back to the
regular path otherwise.  We reproduce that contract with a small logical IR
and a rewrite pass:

    logical plan  --rules-->  physical plan  --execute-->  arrays

Rules implemented (mirroring the paper's):
  R1  Filter(key == lit)  on an indexed table          -> IndexedLookup
  R2  Join(A, B) on key, A indexed                     -> IndexedJoin(build=A)
  R3  Join(A, B) on key, only B indexed                -> IndexedJoin(build=B)
  R4  Join with small probe side                       -> broadcast flavor;
      see the physical-selection rules below (J2/J3) — the logical rewrite
      is identical.
  R5  anything else                                    -> fallback (scan /
      per-query hash join) — "regular execution" in the paper's Fig 2.

Physical-operator selection (DESIGN.md §11): once a logical rewrite fires,
the Planner also picks the *distribution flavor* of the operator — the cost
rules that used to live as caller-facing helpers (``dist.choose_lookup`` /
``dist.choose_join``, which now delegate here):

  L1  lookup on a single partition        -> IndexedLookup (local fused probe)
  L2  dist lookup, Q <  routed_threshold  -> BroadcastLookup (replicate the
      query batch to every shard; exchange latency dominates at small Q)
  L3  dist lookup, Q >= routed_threshold  -> RoutedLookup (shuffle-route each
      query to its owner: ~2Q probe lanes vs broadcast's s*Q)
  L4  as L3 but a hot-key mirror covers max_matches -> HybridLookup (hot
      queries answer locally from the replica arena, only the cold tail
      routes — skew no longer concentrates exchange lanes on one owner,
      DESIGN.md §15)
  J1  join build side on a single partition -> IndexedJoin (local)
  J2  dist join, probe_rows <= bcast_threshold -> BroadcastJoin (replicate
      the probe side — cheaper than shuffling while it is small)
  J3  dist join, probe_rows >  bcast_threshold -> ShuffleJoin (route probe
      rows to their owning shard, paper §III-D)
  J4  as J3 but a hot-key mirror covers max_matches -> HybridJoin (hot
      probe keys join against the mirror locally, cold tail shuffles)

Partition rules (core/partition.py — a PartitionedTable build target;
checked BEFORE the dist rules, since partitions compose with sharding
partition-major/shard-minor):

  P1  point lookup on the partition key      -> PartitionedLookup: route the
      batch host-side, probe ONLY the touched partitions (explain() names
      scanned vs pruned partition ids; tracer keys scan all, in-trace)
  P2  range/list predicate on the partition column in a filter
                                             -> PartitionedFilter: prune the
      partition set by the predicate, then scan-filter the survivors
  P3  equi-join on the partition key         -> PartitionedJoin: per-partition
      local joins — no cross-partition exchange at all; partitions no probe
      key maps to run nothing

Reason strings are UNIFORM across every L/J rule: ``"<rule>: <detail>
[est_fanout=<per-query shard fan-out>]"`` — bcast flavors report ``s``x
(every shard touches the batch), routed/shuffle ``1``x (+2 all_to_alls),
hybrid ``hot:0x cold:1x``; the facade appends ``pending_ring_rows=N`` so
``explain()`` reads the same for every flavor.

``Relation`` leaves accept an ``IndexedTable`` OR a ``DistributedTable``
(duck-typed on ``num_shards``), so one logical tree plans and executes
against either backend; the physical plan records *why* each choice was
made (``explain()``), the analog of Spark's ``df.explain`` the paper uses
to verify rule firing.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import joins
from repro.core import partition as partition_mod
from repro.core.table import IndexedTable


def _is_dist(table) -> bool:
    """Distributed build targets are duck-typed on ``num_shards`` so this
    module never imports ``repro.dist`` at module scope (dist imports the
    planner for its cost rules; execution imports dist lazily)."""
    return table is not None and hasattr(table, "num_shards")


def _is_parted(table) -> bool:
    """Partitioned build targets (core/partition.py PartitionedTable) —
    duck-typed like ``_is_dist`` and checked FIRST: a PartitionedTable has
    no ``num_shards`` itself (its partitions may)."""
    return (table is not None and hasattr(table, "spec")
            and hasattr(table, "parts"))


def _parted_keyed(table) -> bool:
    """True when keyed reads on a partitioned table are well-defined (the
    partition column IS the indexed key — the P1/P3 precondition)."""
    return table.spec.column == table.schema.key


# --- expressions ------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Col:
    name: str


@dataclasses.dataclass(frozen=True)
class Lit:
    value: Any


@dataclasses.dataclass(frozen=True)
class Eq:
    left: Col
    right: Lit | Col


@dataclasses.dataclass(frozen=True)
class Lt:
    left: Col
    right: Lit


# --- logical plan -----------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Relation:
    """Leaf: an IndexedTable, a DistributedTable, or a plain columnar dict.

    ``table`` may be either backend — both expose ``schema``; the planner
    dispatches on ``num_shards`` (duck-typed) when choosing and executing
    physical operators.
    """
    name: str
    table: Any | None = None               # IndexedTable | DistributedTable
    cols: dict | None = None               # plain relation

    @property
    def indexed(self) -> bool:
        return self.table is not None

    @property
    def distributed(self) -> bool:
        return _is_dist(self.table)

    @property
    def key(self) -> str | None:
        return self.table.schema.key if self.indexed else None

    def num_rows(self) -> int:
        """Host-side row count (cardinality input to the J2/J3 cost rule)."""
        if self.indexed:
            return int(np.asarray(self.table.num_rows()))
        if self.cols:
            return int(np.shape(next(iter(self.cols.values())))[0])
        return 0


@dataclasses.dataclass(frozen=True)
class Filter:
    child: Any
    pred: Eq | Lt


@dataclasses.dataclass(frozen=True)
class Project:
    child: Any
    names: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Join:
    left: Any
    right: Any
    on: str


@dataclasses.dataclass(frozen=True)
class Aggregate:
    child: Any
    op: str
    col: str


# --- physical plan ----------------------------------------------------------

@dataclasses.dataclass
class Physical:
    kind: str            # IndexedLookup | IndexedJoin | ScanFilter | HashJoin | ...
    reason: str
    node: Any
    children: tuple = ()
    meta: Any = None     # operator payload (e.g. P2's kept partition indices)

    def explain(self, depth: int = 0) -> str:
        pad = "  " * depth
        out = f"{pad}{self.kind}  [{self.reason}]\n"
        for c in self.children:
            out += c.explain(depth + 1)
        return out


class Planner:
    """Rule-based rewriter + executor + physical-operator selector.

    ``routed_threshold`` / ``bcast_threshold`` are the distribution cost
    knobs (rules L2/L3 and J2/J3); ``rt`` is the ``dist.mesh.Runtime``
    every distributed physical operator executes under (None = the vmap
    emulation backend).
    """

    def __init__(self, *, max_matches: int = 64,
                 routed_threshold: int = 4096,
                 bcast_threshold: int = 1_000_000, rt=None):
        self.max_matches = max_matches
        self.routed_threshold = routed_threshold
        self.bcast_threshold = bcast_threshold
        self.rt = rt

    # -- physical-operator selection (the dist.choose_* rules, moved) --------
    def _hybrid_ok(self, table) -> bool:
        """True when a hot-key mirror is attached that fully answers this
        planner's ``max_matches`` (the L4/J4 precondition — a mirror
        storing fewer matches per key cannot substitute for routing)."""
        rep = getattr(table, "replica", None)
        return rep is not None and self.max_matches <= rep.max_matches

    def lookup_flavor(self, num_shards: int, num_queries: int, *,
                      hybrid_ok: bool = False) -> tuple[str, str]:
        """(op, reason) for a point lookup: bcast vs routed vs hybrid
        (L2/L3/L4)."""
        if num_shards > 1 and num_queries >= self.routed_threshold:
            if hybrid_ok:
                return ("hybrid",
                        f"L4: Q={num_queries} >= routed_threshold="
                        f"{self.routed_threshold} and a hot-key mirror is "
                        f"attached -> answer hot queries from the replica "
                        f"arena, route only the cold tail "
                        f"[est_fanout=hot:0x cold:1x]")
            return ("routed",
                    f"L3: Q={num_queries} >= routed_threshold="
                    f"{self.routed_threshold} -> route each query to its "
                    f"owner (~2Q probe lanes vs broadcast's "
                    f"{num_shards}xQ) [est_fanout=1x]")
        return ("bcast",
                f"L2: Q={num_queries} < routed_threshold="
                f"{self.routed_threshold} -> broadcast the batch to all "
                f"{num_shards} shards (exchange latency dominates) "
                f"[est_fanout={num_shards}x]")

    def join_flavor(self, probe_rows: int, *, num_shards: int | None = None,
                    hybrid_ok: bool = False) -> tuple[str, str]:
        """(op, reason) for an equi-join probe side: bcast vs shuffle vs
        hybrid (J2/J3/J4, paper §III-D)."""
        fan = "s" if num_shards is None else str(int(num_shards))
        if probe_rows <= self.bcast_threshold:
            return ("bcast",
                    f"J2: probe_rows={probe_rows} <= bcast_threshold="
                    f"{self.bcast_threshold} -> replicate the probe side "
                    f"[est_fanout={fan}x]")
        if hybrid_ok:
            return ("hybrid",
                    f"J4: probe_rows={probe_rows} > bcast_threshold="
                    f"{self.bcast_threshold} and a hot-key mirror is "
                    f"attached -> join hot probe keys against the mirror "
                    f"locally, shuffle only the cold tail "
                    f"[est_fanout=hot:0x cold:1x]")
        return ("shuffle",
                f"J3: probe_rows={probe_rows} > bcast_threshold="
                f"{self.bcast_threshold} -> shuffle probe rows to their "
                f"owning shard [est_fanout=1x]")

    # -- partition pruning (rules P1-P3) --------------------------------------
    def _prune_sets(self, spec, touched) -> str:
        scanned = [spec.ids[p] for p in touched]
        pruned = [pid for pid in spec.ids if pid not in scanned]
        return (f"scanned={','.join(scanned) or '-'}; "
                f"pruned={','.join(pruned) or '-'}")

    def _inner_flavor(self, table, num_queries: int) -> tuple[str, str]:
        flavor = partition_mod.part_flavor(
            table, num_queries, routed_threshold=self.routed_threshold)
        detail = {
            "local": "local fused probe",
            "bcast": f"bcast across {table.shards_per_partition} shards",
            "routed": f"routed exchange over "
                      f"{table.shards_per_partition} shards",
        }[flavor]
        return flavor, detail

    def partitioned_lookup_plan(self, table, num_queries: int,
                                keys=None) -> Physical:
        """Rule P1: route the key batch on the partition spec and name the
        scanned vs pruned partitions; tracer (or absent) keys cannot be
        routed host-side and scan every partition in-trace."""
        partition_mod._check_keyed(table, "lookup")
        spec = table.spec
        _, inner = self._inner_flavor(table, num_queries)
        if keys is not None and not isinstance(keys, jax.core.Tracer):
            dest = spec.route_host(np.asarray(keys))
            touched = sorted(int(p) for p in np.unique(dest[dest >= 0]))
            why = (f"P1: point lookup on partition key {spec.column!r} -> "
                   f"pruned to {len(touched)}/{spec.num_partitions} "
                   f"partitions [{self._prune_sets(spec, touched)}; "
                   f"per-partition {inner}]")
            return Physical("PartitionedLookup", why, table, meta=touched)
        why = (f"P1: point lookup on partition key {spec.column!r}, keys "
               f"traced -> all {spec.num_partitions} partitions scanned "
               f"in-trace [per-partition {inner}]")
        return Physical("PartitionedLookup", why, table)

    def partitioned_join_plan(self, table, probe_rows: int,
                              keys=None) -> Physical:
        """Rule P3: per-partition local joins — the probe batch routes on
        the partition key, so there is NO cross-partition exchange."""
        partition_mod._check_keyed(table, "join")
        spec = table.spec
        _, inner = self._inner_flavor(table, probe_rows)
        if keys is not None and not isinstance(keys, jax.core.Tracer):
            dest = spec.route_host(np.asarray(keys))
            touched = sorted(int(p) for p in np.unique(dest[dest >= 0]))
            why = (f"P3: join on partition key {spec.column!r} -> "
                   f"per-partition local joins, no cross-partition "
                   f"exchange [{self._prune_sets(spec, touched)}; "
                   f"per-partition {inner}]")
            return Physical("PartitionedJoin", why, table, meta=touched)
        why = (f"P3: join on partition key {spec.column!r}, probe keys "
               f"traced -> per-partition local joins over all "
               f"{spec.num_partitions} partitions, no cross-partition "
               f"exchange [per-partition {inner}]")
        return Physical("PartitionedJoin", why, table)

    def partitioned_filter_plan(self, table, pred) -> Physical | None:
        """Rule P2: a range/list predicate on the partition column prunes
        the partition set before the scan (None = P2 does not apply)."""
        spec = table.spec
        if isinstance(pred, Eq) and isinstance(pred.right, Lit) \
                and pred.left.name == spec.column:
            kept, op = spec.prune_eq(pred.right.value), "eq"
        elif isinstance(pred, Lt) and pred.left.name == spec.column:
            kept, op = spec.prune_lt(pred.right.value), "range"
        else:
            return None
        why = (f"P2: {op} predicate on partition column {spec.column!r} "
               f"-> scan pruned to {len(kept)}/{spec.num_partitions} "
               f"partitions [{self._prune_sets(spec, kept)}]")
        return Physical("PartitionedFilter", why, None, meta=tuple(kept))

    def physical_lookup(self, table, num_queries: int,
                        keys=None) -> Physical:
        """Physical operator for a point-lookup over ``table`` (any
        backend) at the given query-batch size."""
        if _is_parted(table):
            return self.partitioned_lookup_plan(table, num_queries, keys)
        if not _is_dist(table):
            return Physical("IndexedLookup",
                            "L1: single partition -> local fused probe "
                            "[est_fanout=1x]",
                            table)
        op, why = self.lookup_flavor(int(table.num_shards), num_queries,
                                     hybrid_ok=self._hybrid_ok(table))
        kind = {"routed": "RoutedLookup", "hybrid": "HybridLookup",
                "bcast": "BroadcastLookup"}[op]
        return Physical(kind, why, table)

    def physical_join(self, table, probe_rows: int, keys=None) -> Physical:
        """Physical operator for an indexed equi-join with ``table`` as the
        build side and a ``probe_rows``-row probe side."""
        if _is_parted(table):
            return self.partitioned_join_plan(table, probe_rows, keys)
        if not _is_dist(table):
            return Physical("IndexedJoin",
                            "J1: single partition -> local indexed join "
                            "[est_fanout=1x]",
                            table)
        op, why = self.join_flavor(probe_rows,
                                   num_shards=int(table.num_shards),
                                   hybrid_ok=self._hybrid_ok(table))
        kind = {"shuffle": "ShuffleJoin", "hybrid": "HybridJoin",
                "bcast": "BroadcastJoin"}[op]
        return Physical(kind, why, table)

    # -- rewrite --------------------------------------------------------------
    def plan(self, node) -> Physical:
        if isinstance(node, Relation):
            kind = "IndexedScan" if node.indexed else "Scan"
            return Physical(kind, "leaf", node)
        if isinstance(node, Filter):
            child = node.child
            parted = isinstance(child, Relation) and _is_parted(child.table)
            key_eq = (isinstance(child, Relation) and child.indexed
                      and isinstance(node.pred, Eq)
                      and node.pred.left.name == child.key
                      and isinstance(node.pred.right, Lit))
            if key_eq and (not parted or _parted_keyed(child.table)):
                reason = f"R1: eq-filter on indexed key '{child.key}'"
                keys = (np.asarray([node.pred.right.value], np.int64)
                        if parted else None)
                flavor = self.physical_lookup(child.table, 1, keys=keys)
                if flavor.kind != "IndexedLookup":
                    reason += f"; {flavor.reason}"
                return Physical(flavor.kind, reason, node,
                                (self.plan(child),), meta=flavor.meta)
            if parted:
                p2 = self.partitioned_filter_plan(child.table, node.pred)
                if p2 is not None:
                    return dataclasses.replace(
                        p2, node=node, children=(self.plan(child),))
            return Physical("ScanFilter", "R5: fallback (non-key or "
                            "non-eq predicate)", node,
                            (self.plan(node.child),))
        if isinstance(node, Join):
            l, r = node.left, node.right

            def _joinable(rel):
                return (isinstance(rel, Relation) and rel.indexed
                        and rel.key == node.on
                        and (not _is_parted(rel.table)
                             or _parted_keyed(rel.table)))

            l_idx, r_idx = _joinable(l), _joinable(r)
            if l_idx or r_idx:
                build, probe = (l, r) if l_idx else (r, l)
                rule = "R2: left" if l_idx else "R3: right"
                reason = (f"{rule} side indexed on '{node.on}' -> "
                          f"build side")
                probe_keys = (probe.cols.get(node.on)
                              if isinstance(probe, Relation)
                              and probe.cols is not None else None)
                flavor = self.physical_join(build.table,
                                            _estimate_rows(probe),
                                            keys=probe_keys)
                if flavor.kind != "IndexedJoin":
                    reason += f"; {flavor.reason}"
                return Physical(flavor.kind, reason, node,
                                (self.plan(build), self.plan(probe)),
                                meta=flavor.meta)
            return Physical("HashJoin", "R5: no usable index -> per-query "
                            "hash build", node,
                            (self.plan(l), self.plan(r)))
        if isinstance(node, Project):
            return Physical("Project", "narrow", node,
                            (self.plan(node.child),))
        if isinstance(node, Aggregate):
            return Physical("Aggregate", node.op, node,
                            (self.plan(node.child),))
        raise TypeError(f"unknown logical node {node!r}")

    # -- execute ---------------------------------------------------------------
    def execute(self, node):
        return self._exec(self.plan(node))

    def _exec(self, p: Physical):
        n = p.node
        if p.kind in ("IndexedScan", "Scan"):
            return n  # relations are consumed by parents
        if p.kind == "PartitionedLookup":
            rel = n.child
            key = jnp.asarray([n.pred.right.value], jnp.int64)
            cols, valid = partition_mod.lookup_partitioned(
                rel.table, key, max_matches=self.max_matches, rt=self.rt,
                routed_threshold=self.routed_threshold)
            return {k: v[0] for k, v in cols.items()}, valid[0]
        if p.kind == "PartitionedFilter":
            rel = n.child
            cols, valid = partition_mod.collect_partitions(
                rel.table, p.meta, rt=self.rt)
            pred_v = _eval_pred(n.pred, cols)
            return cols, valid & pred_v
        if p.kind == "PartitionedJoin":
            build_rel = p.children[0].node
            probe_rel = p.children[1].node
            probe_cols, probe_valid = _materialize(probe_rel, rt=self.rt)
            bc, pc, valid = partition_mod.join_partitioned(
                build_rel.table, probe_cols, n.on,
                max_matches=self.max_matches, rt=self.rt,
                routed_threshold=self.routed_threshold)
            valid = valid & probe_valid[:, None]
            merged = {**{f"b_{k}": v for k, v in bc.items()},
                      **{f"p_{k}": v for k, v in pc.items()}}
            return merged, valid
        if p.kind in ("IndexedLookup", "BroadcastLookup", "RoutedLookup",
                      "HybridLookup"):
            rel = n.child
            key = jnp.asarray([n.pred.right.value], jnp.int64)
            if p.kind == "IndexedLookup":
                cols, valid = joins.indexed_lookup(
                    rel.table, key, max_matches=self.max_matches)
            else:
                from repro.dist import dtable as _dd
                if p.kind == "BroadcastLookup":
                    cols, valid, _ = _dd.lookup(
                        rel.table, key, max_matches=self.max_matches,
                        rt=self.rt)
                else:
                    flat = (_dd.lookup_hybrid_flat
                            if p.kind == "HybridLookup"
                            else _dd.lookup_routed_flat)
                    cols, valid = flat(
                        rel.table, key, max_matches=self.max_matches,
                        rt=self.rt)
            return {k: v[0] for k, v in cols.items()}, valid[0]
        if p.kind == "ScanFilter":
            rel = n.child
            cols, valid = _materialize(rel, rt=self.rt)
            pred_v = _eval_pred(n.pred, cols)
            return cols, valid & pred_v
        if p.kind in ("IndexedJoin", "BroadcastJoin", "ShuffleJoin",
                      "HybridJoin"):
            build_rel = p.children[0].node
            probe_rel = p.children[1].node
            probe_cols, probe_valid = _materialize(probe_rel, rt=self.rt)
            if p.kind == "IndexedJoin":
                bc, pc, valid = joins.indexed_join(
                    build_rel.table, probe_cols, n.on,
                    max_matches=self.max_matches)
            else:
                from repro.dist import dtable as _dd
                join_fn = {"BroadcastJoin": _dd.indexed_join_bcast,
                           "ShuffleJoin": _dd.indexed_join_routed,
                           "HybridJoin": _dd.indexed_join_hybrid}[p.kind]
                bc, pc, valid = join_fn(build_rel.table, probe_cols, n.on,
                                        max_matches=self.max_matches,
                                        rt=self.rt)
            valid = valid & probe_valid[:, None]
            merged = {**{f"b_{k}": v for k, v in bc.items()},
                      **{f"p_{k}": v for k, v in pc.items()}}
            return merged, valid
        if p.kind == "HashJoin":
            lc, lv = _materialize(p.children[0].node, rt=self.rt)
            rc, rv = _materialize(p.children[1].node, rt=self.rt)
            bc, pc, valid = joins.hash_join(lc, n.on, rc, n.on,
                                            max_matches=self.max_matches)
            valid = valid & rv[:, None]
            merged = {**{f"b_{k}": v for k, v in bc.items()},
                      **{f"p_{k}": v for k, v in pc.items()}}
            return merged, valid
        if p.kind == "Project":
            cols, valid = self._exec(p.children[0])
            return {k: v for k, v in cols.items()
                    if k in n.names or k.removeprefix("b_") in n.names
                    or k.removeprefix("p_") in n.names}, valid
        if p.kind == "Aggregate":
            cols, valid = self._exec(p.children[0])
            name = n.col
            for cand in (name, f"b_{name}", f"p_{name}"):
                if cand in cols:
                    return joins.aggregate(cols[cand], valid, n.op)
            raise KeyError(name)
        raise TypeError(p.kind)


def _estimate_rows(node) -> int:
    """Upper-bound row estimate for the J2/J3 cost rule — recursive, so a
    probe side wrapped in Filter/Project still reports its source
    cardinality instead of silently planning as a zero-row broadcast."""
    if isinstance(node, Relation):
        return node.num_rows()
    if isinstance(node, (Filter, Project, Aggregate)):
        return _estimate_rows(node.child)
    if isinstance(node, Join):
        return _estimate_rows(node.left) + _estimate_rows(node.right)
    return 0


def _materialize(rel: Relation, rt=None):
    if rel.indexed and _is_parted(rel.table):
        return partition_mod.collect_partitions(rel.table, rt=rt)
    if rel.distributed:
        from repro.dist import dtable as _dd
        cols = {k: jnp.asarray(v)
                for k, v in _dd.collect_cols(rel.table, rt=rt).items()}
        n = next(iter(cols.values())).shape[0]
        return cols, jnp.ones((n,), bool)
    if rel.indexed:
        all_cols = {}
        for name in rel.table.schema.names:
            vals, valid = rel.table.scan_column(name)
            all_cols[name] = vals
        return all_cols, valid
    cols = {k: jnp.asarray(v) for k, v in rel.cols.items()}
    n = next(iter(cols.values())).shape[0]
    return cols, jnp.ones((n,), bool)


def _eval_pred(pred, cols):
    if isinstance(pred, Eq):
        return cols[pred.left.name] == pred.right.value
    if isinstance(pred, Lt):
        return cols[pred.left.name] < pred.right.value
    raise TypeError(pred)
