"""Query planner — the Catalyst-integration analog (paper §III-B, Fig 2).

The paper hooks Spark's Catalyst with *optimization rules* that rewrite
logical operators into indexed physical operators whenever an equality
predicate or equi-join touches an indexed column, falling back to the
regular path otherwise.  We reproduce that contract with a small logical IR
and a rewrite pass:

    logical plan  --rules-->  physical plan  --execute-->  arrays

Rules implemented (mirroring the paper's):
  R1  Filter(key == lit)  on an indexed table          -> IndexedLookup
  R2  Join(A, B) on key, A indexed                     -> IndexedJoin(build=A)
  R3  Join(A, B) on key, only B indexed                -> IndexedJoin(build=B)
  R4  Join with small probe side                       -> broadcast flavor is
      a distribution-layer decision (dist/dtable.py); the logical rewrite is
      identical.
  R5  anything else                                    -> fallback (scan /
      per-query hash join) — "regular execution" in the paper's Fig 2.

The physical plan records *why* each choice was made (``explain()``), the
analog of Spark's ``df.explain`` the paper uses to verify rule firing.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from repro.core import joins
from repro.core.table import IndexedTable


# --- expressions ------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Col:
    name: str


@dataclasses.dataclass(frozen=True)
class Lit:
    value: Any


@dataclasses.dataclass(frozen=True)
class Eq:
    left: Col
    right: Lit | Col


@dataclasses.dataclass(frozen=True)
class Lt:
    left: Col
    right: Lit


# --- logical plan -----------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Relation:
    """Leaf: either an IndexedTable or a plain columnar dict."""
    name: str
    table: IndexedTable | None = None      # indexed relation
    cols: dict | None = None               # plain relation

    @property
    def indexed(self) -> bool:
        return self.table is not None

    @property
    def key(self) -> str | None:
        return self.table.schema.key if self.indexed else None


@dataclasses.dataclass(frozen=True)
class Filter:
    child: Any
    pred: Eq | Lt


@dataclasses.dataclass(frozen=True)
class Project:
    child: Any
    names: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Join:
    left: Any
    right: Any
    on: str


@dataclasses.dataclass(frozen=True)
class Aggregate:
    child: Any
    op: str
    col: str


# --- physical plan ----------------------------------------------------------

@dataclasses.dataclass
class Physical:
    kind: str            # IndexedLookup | IndexedJoin | ScanFilter | HashJoin | ...
    reason: str
    node: Any
    children: tuple = ()

    def explain(self, depth: int = 0) -> str:
        pad = "  " * depth
        out = f"{pad}{self.kind}  [{self.reason}]\n"
        for c in self.children:
            out += c.explain(depth + 1)
        return out


class Planner:
    """Rule-based rewriter + executor."""

    def __init__(self, *, max_matches: int = 64):
        self.max_matches = max_matches

    # -- rewrite --------------------------------------------------------------
    def plan(self, node) -> Physical:
        if isinstance(node, Relation):
            kind = "IndexedScan" if node.indexed else "Scan"
            return Physical(kind, "leaf", node)
        if isinstance(node, Filter):
            child = node.child
            if (isinstance(child, Relation) and child.indexed
                    and isinstance(node.pred, Eq)
                    and node.pred.left.name == child.key
                    and isinstance(node.pred, Eq)
                    and isinstance(node.pred.right, Lit)):
                return Physical("IndexedLookup",
                                f"R1: eq-filter on indexed key "
                                f"'{child.key}'", node,
                                (self.plan(child),))
            return Physical("ScanFilter", "R5: fallback (non-key or "
                            "non-eq predicate)", node,
                            (self.plan(node.child),))
        if isinstance(node, Join):
            l, r = node.left, node.right
            l_idx = isinstance(l, Relation) and l.indexed and l.key == node.on
            r_idx = isinstance(r, Relation) and r.indexed and r.key == node.on
            if l_idx:
                return Physical("IndexedJoin", "R2: left side indexed on "
                                f"'{node.on}' -> build side", node,
                                (self.plan(l), self.plan(r)))
            if r_idx:
                return Physical("IndexedJoin", "R3: right side indexed on "
                                f"'{node.on}' -> build side", node,
                                (self.plan(r), self.plan(l)))
            return Physical("HashJoin", "R5: no usable index -> per-query "
                            "hash build", node,
                            (self.plan(l), self.plan(r)))
        if isinstance(node, Project):
            return Physical("Project", "narrow", node,
                            (self.plan(node.child),))
        if isinstance(node, Aggregate):
            return Physical("Aggregate", node.op, node,
                            (self.plan(node.child),))
        raise TypeError(f"unknown logical node {node!r}")

    # -- execute ---------------------------------------------------------------
    def execute(self, node):
        return self._exec(self.plan(node))

    def _exec(self, p: Physical):
        n = p.node
        if p.kind in ("IndexedScan", "Scan"):
            return n  # relations are consumed by parents
        if p.kind == "IndexedLookup":
            rel = n.child
            key = jnp.asarray([n.pred.right.value], jnp.int64)
            cols, valid = joins.indexed_lookup(rel.table, key,
                                               max_matches=self.max_matches)
            return {k: v[0] for k, v in cols.items()}, valid[0]
        if p.kind == "ScanFilter":
            rel = n.child
            cols, valid = _materialize(rel)
            pred_v = _eval_pred(n.pred, cols)
            return cols, valid & pred_v
        if p.kind == "IndexedJoin":
            build_rel = p.children[0].node
            probe_rel = p.children[1].node
            probe_cols, probe_valid = _materialize(probe_rel)
            bc, pc, valid = joins.indexed_join(
                build_rel.table, probe_cols, n.on,
                max_matches=self.max_matches)
            valid = valid & probe_valid[:, None]
            merged = {**{f"b_{k}": v for k, v in bc.items()},
                      **{f"p_{k}": v for k, v in pc.items()}}
            return merged, valid
        if p.kind == "HashJoin":
            lc, lv = _materialize(p.children[0].node)
            rc, rv = _materialize(p.children[1].node)
            bc, pc, valid = joins.hash_join(lc, n.on, rc, n.on,
                                            max_matches=self.max_matches)
            valid = valid & rv[:, None]
            merged = {**{f"b_{k}": v for k, v in bc.items()},
                      **{f"p_{k}": v for k, v in pc.items()}}
            return merged, valid
        if p.kind == "Project":
            cols, valid = self._exec(p.children[0])
            return {k: v for k, v in cols.items()
                    if k in n.names or k.removeprefix("b_") in n.names
                    or k.removeprefix("p_") in n.names}, valid
        if p.kind == "Aggregate":
            cols, valid = self._exec(p.children[0])
            name = n.col
            for cand in (name, f"b_{name}", f"p_{name}"):
                if cand in cols:
                    return joins.aggregate(cols[cand], valid, n.op)
            raise KeyError(name)
        raise TypeError(p.kind)


def _materialize(rel: Relation):
    if rel.indexed:
        all_cols = {}
        for name in rel.table.schema.names:
            vals, valid = rel.table.scan_column(name)
            all_cols[name] = vals
        return all_cols, valid
    cols = {k: jnp.asarray(v) for k, v in rel.cols.items()}
    n = next(iter(cols.values())).shape[0]
    return cols, jnp.ones((n,), bool)


def _eval_pred(pred, cols):
    if isinstance(pred, Eq):
        return cols[pred.left.name] == pred.right.value
    if isinstance(pred, Lt):
        return cols[pred.left.name] < pred.right.value
    raise TypeError(pred)
