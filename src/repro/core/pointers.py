"""Packed row pointers for the Indexed DataFrame.

The paper packs ``(row_batch_number, offset_within_batch, prev_row_size)``
into dense 64-bit integers (paper §III-C).  TPUs have no fast int64 ALU
path, so we adapt: a pointer is a *flat int32 row id* over the ordered list
of fixed-capacity row batches::

    row_id = batch_id * rows_per_batch + offset      (NULL = -1)

``rows_per_batch`` is a power of two so batch/offset recovery is a
shift/mask — the same dense-packing trick, TPU-native.  int32 addresses
2**31 rows per partition, which matches the paper's own per-core bound
("2^31 row batches ... 4 MB each" gives the same order of addressable data
once scaled to per-partition terms).
"""

from __future__ import annotations

import jax.numpy as jnp

NULL_PTR = jnp.int32(-1)
PTR_DTYPE = jnp.int32


def pack(batch_id, offset, *, log2_rows_per_batch: int):
    """Pack (batch_id, offset) into a flat int32 row pointer."""
    batch_id = jnp.asarray(batch_id, PTR_DTYPE)
    offset = jnp.asarray(offset, PTR_DTYPE)
    return (batch_id << log2_rows_per_batch) | offset


def unpack(ptr, *, log2_rows_per_batch: int):
    """Unpack a flat row pointer into (batch_id, offset).

    NULL pointers unpack to (-1, -1) so downstream gathers can mask on
    either component.
    """
    ptr = jnp.asarray(ptr, PTR_DTYPE)
    mask = ptr >= 0
    batch_id = jnp.where(mask, ptr >> log2_rows_per_batch, NULL_PTR)
    offset = jnp.where(mask, ptr & ((1 << log2_rows_per_batch) - 1), NULL_PTR)
    return batch_id, offset


def is_null(ptr):
    return ptr < 0
