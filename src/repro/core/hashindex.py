"""Dense bucketized hash index — the TPU-native replacement for the cTrie.

The paper's cTrie (§III-C) maps ``key -> pointer to the *latest* row holding
that key``; rows sharing a key are chained through *backward pointers*.  A
pointer-chasing trie does not vectorize on a TPU, so we keep the contract and
swap the mechanism (DESIGN.md §2):

* ``bucket_keys  : [num_buckets, slots] int64``  (EMPTY = int64 min)
* ``bucket_ptrs  : [num_buckets, slots] int32``  (flat row id, NULL = -1)

A probe is one gather of a ``[Q, slots]`` tile followed by a vector compare —
one VREG-wide operation per query tile instead of a pointer walk.  Inserts
are *bulk and functional*: hash → lexsort → segment-rank → one scatter.  The
concurrency the cTrie gets from CAS, we get from turning contention into a
parallel scan; the lock-free *snapshot* becomes delta chaining in
``table.py``.

Collision policy: each bucket has ``slots`` lanes.  If a bulk build overflows
a bucket, the build reports ``overflow_count`` and the host-level wrapper
retries with 2x buckets (the paper's index (re)build is likewise a heavyweight
host-coordinated operation).  Probes are exact for every key that was
inserted; overflow is therefore a *build-time* failure mode only, never a
silent wrong answer at query time.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.pointers import NULL_PTR, PTR_DTYPE

EMPTY_KEY = jnp.int64(np.iinfo(np.int64).min)
DEFAULT_SLOTS = 8


@partial(jax.tree_util.register_dataclass, data_fields=["bucket_keys", "bucket_ptrs"],
         meta_fields=["num_buckets", "slots"])
@dataclasses.dataclass(frozen=True)
class HashIndex:
    """Immutable dense hash index over one table partition."""

    bucket_keys: jax.Array  # [num_buckets, slots] int64
    bucket_ptrs: jax.Array  # [num_buckets, slots] int32 (flat row ids)
    num_buckets: int
    slots: int

    @property
    def nbytes(self) -> int:
        return self.bucket_keys.size * 8 + self.bucket_ptrs.size * 4


def empty_index(num_buckets: int, slots: int = DEFAULT_SLOTS) -> HashIndex:
    return HashIndex(
        bucket_keys=jnp.full((num_buckets, slots), EMPTY_KEY, jnp.int64),
        bucket_ptrs=jnp.full((num_buckets, slots), NULL_PTR, PTR_DTYPE),
        num_buckets=num_buckets,
        slots=slots,
    )


# ---------------------------------------------------------------------------
# Bulk build
# ---------------------------------------------------------------------------

def _segment_rank(sorted_ids):
    """Rank of each element within its run of equal ``sorted_ids``."""
    n = sorted_ids.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]])
    start_pos = jax.lax.associative_scan(jnp.maximum,
                                         jnp.where(is_start, idx, -1))
    return idx - start_pos


@partial(jax.jit, static_argnames=("num_buckets", "slots"))
def _build_arrays(keys, row_ids, valid, num_buckets: int, slots: int):
    """One fused build pass.  Returns (bucket_keys, bucket_ptrs, prev, overflow).

    ``prev`` is the backward-pointer array *scattered by row id* — callers
    hand in row ids that are already offset into the partition-global row
    space, so ``prev`` must be combined by the caller (table.py) with the
    destination capacity.  Here we return (prev_src_rows, prev_vals) pairs
    instead of a dense array so the caller controls the scatter target.
    """
    n = keys.shape[0]
    keys = jnp.where(valid, keys, EMPTY_KEY)

    # --- backward pointers: lexsort by (key, row_id) -----------------------
    order = jnp.lexsort((row_ids, keys))
    k_s, r_s, v_s = keys[order], row_ids[order], valid[order]
    same_as_prev = jnp.concatenate(
        [jnp.zeros((1,), bool), (k_s[1:] == k_s[:-1]) & v_s[1:] & v_s[:-1]])
    prev_vals = jnp.where(same_as_prev, jnp.concatenate(
        [jnp.full((1,), NULL_PTR), r_s[:-1].astype(PTR_DTYPE)]), NULL_PTR)
    # Invalid rows scatter to int32 max so any caller-side offset still
    # lands out of range and is dropped.
    prev_rows = jnp.where(v_s, r_s.astype(PTR_DTYPE), jnp.int32(2**31 - 1))

    # --- head per key: last element of each equal-key run ------------------
    is_head = jnp.concatenate([k_s[1:] != k_s[:-1], jnp.ones((1,), bool)]) & v_s

    # --- bucket placement ---------------------------------------------------
    bucket = hashing.bucket_hash(k_s, num_buckets)
    # Sort heads by bucket; non-heads sort to the end (bucket = num_buckets).
    bucket_or_inf = jnp.where(is_head, bucket, jnp.int32(num_buckets))
    order2 = jnp.argsort(bucket_or_inf, stable=True)
    b2, k2, r2, head2 = (bucket_or_inf[order2], k_s[order2], r_s[order2],
                         is_head[order2])
    rank = _segment_rank(b2)
    overflow = jnp.sum((rank >= slots) & head2)
    ok = head2 & (rank < slots)
    flat = jnp.where(ok, b2 * slots + jnp.minimum(rank, slots - 1),
                     jnp.int32(num_buckets * slots))  # out-of-range = drop

    bucket_keys = jnp.full((num_buckets * slots,), EMPTY_KEY, jnp.int64)
    bucket_ptrs = jnp.full((num_buckets * slots,), NULL_PTR, PTR_DTYPE)
    bucket_keys = bucket_keys.at[flat].set(k2, mode="drop")
    bucket_ptrs = bucket_ptrs.at[flat].set(r2.astype(PTR_DTYPE), mode="drop")
    return (bucket_keys.reshape(num_buckets, slots),
            bucket_ptrs.reshape(num_buckets, slots),
            prev_rows, prev_vals, overflow)


def arena_insert_plan(bucket_keys, head_keys, is_head):
    """Slot placement for inserting per-key head pointers into a *live*
    bucket table (the arena append path, DESIGN.md §4).

    The bulk build (`_build_arrays`) packs each bucket's occupied slots
    left-to-right, and arena inserts preserve that invariant, so placement
    is branch-free: a head whose key already sits in the table reuses its
    slot (the pointer is overwritten with the newer row); a new key takes
    ``occupancy + rank`` where ``rank`` orders the batch's new keys within
    their bucket.  Returns ``(flat_slot [d] int32, overflow scalar)`` —
    ``flat_slot`` indexes the flattened ``[nb * slots]`` planes and is set
    to ``nb * slots`` (out of range, scatter-dropped) for non-head lanes
    and overflowing inserts.  Overflow is *counted, never silent* — the
    same build-time-only failure contract as the bulk build; the host
    wrapper reacts by promoting the arena (more buckets), so probes stay
    exact for every inserted key.
    """
    nb, slots = bucket_keys.shape
    b = hashing.bucket_hash(head_keys, nb)
    row_keys = bucket_keys[b]                               # [d, slots]
    match = ((row_keys == head_keys[:, None]) & is_head[:, None]
             & (head_keys != EMPTY_KEY)[:, None])
    exists = match.any(axis=1)
    slot_exist = jnp.argmax(match, axis=1).astype(jnp.int32)
    occ = jnp.sum(bucket_keys != EMPTY_KEY, axis=1).astype(jnp.int32)
    new_head = is_head & ~exists
    b_or_inf = jnp.where(new_head, b, jnp.int32(nb))
    order = jnp.argsort(b_or_inf, stable=True)
    rank = (jnp.zeros(b.shape, jnp.int32)
            .at[order].set(_segment_rank(b_or_inf[order])))
    slot_new = occ[b] + rank
    overflow = jnp.sum(new_head & (slot_new >= slots))
    slot = jnp.where(exists, slot_exist, slot_new)
    ok = is_head & (slot < slots)
    flat = jnp.where(ok, b * slots + slot, jnp.int32(nb * slots))
    return flat, overflow


def suggest_num_buckets(n_keys: int, slots: int = DEFAULT_SLOTS,
                        load: float = 0.25) -> int:
    """Power-of-two bucket count targeting ``load`` mean occupancy/slot."""
    want = max(16, int(n_keys / max(1, slots * load)))
    return 1 << (want - 1).bit_length()


def build_index(keys, row_ids, *, valid=None, num_buckets: int | None = None,
                slots: int = DEFAULT_SLOTS, max_retries: int = 4):
    """Host-coordinated build with overflow-doubling retry.

    Returns ``(HashIndex, prev_rows, prev_vals)`` — the prev pairs are the
    backward-pointer scatter the caller applies to its row space.
    """
    keys = jnp.asarray(keys, jnp.int64)
    row_ids = jnp.asarray(row_ids, PTR_DTYPE)
    if valid is None:
        valid = jnp.ones(keys.shape, bool)
    nb = num_buckets or suggest_num_buckets(int(keys.shape[0]), slots)
    for _ in range(max_retries):
        bk, bp, prev_rows, prev_vals, overflow = _build_arrays(
            keys, row_ids, valid, nb, slots)
        if int(overflow) == 0:
            return (HashIndex(bk, bp, nb, slots), prev_rows, prev_vals)
        nb *= 2
    raise RuntimeError(
        f"hash index build overflowed after {max_retries} doublings "
        f"(final num_buckets={nb}); pathological key distribution?")


# ---------------------------------------------------------------------------
# Probe (pure-JAX reference path; the Pallas kernel in kernels/hash_probe.py
# implements the same contract and is swept against probe() in tests)
# ---------------------------------------------------------------------------

def probe(index: HashIndex, query_keys) -> jax.Array:
    """Latest row id per query key (NULL_PTR where absent).  [Q] int32."""
    q = jnp.asarray(query_keys, jnp.int64)
    b = hashing.bucket_hash(q, index.num_buckets)
    keys_b = index.bucket_keys[b]                       # [Q, S] gather
    ptrs_b = index.bucket_ptrs[b]
    hit = (keys_b == q[:, None]) & (q[:, None] != EMPTY_KEY)
    slot = jnp.argmax(hit, axis=1)
    ptr = jnp.take_along_axis(ptrs_b, slot[:, None], axis=1)[:, 0]
    return jnp.where(hit.any(axis=1), ptr, NULL_PTR)


def chain_walk(prev, head_ptrs, max_matches: int):
    """Follow backward pointers: [Q] head ptrs -> [Q, max_matches] row ids.

    Row ids are emitted newest-first and padded with NULL_PTR, mirroring the
    paper's traversal of the per-key linked list.  ``truncated`` flags keys
    whose chain is longer than ``max_matches``.
    """
    prev = jnp.asarray(prev, PTR_DTYPE)
    cur = jnp.asarray(head_ptrs, PTR_DTYPE)

    def step(cur, _):
        nxt = jnp.where(cur >= 0, prev[jnp.maximum(cur, 0)], NULL_PTR)
        return nxt, cur

    last, rows = jax.lax.scan(step, cur, None, length=max_matches)
    truncated = last >= 0
    return jnp.moveaxis(rows, 0, 1), truncated


def match_counts(prev, head_ptrs, max_matches: int):
    rows, _ = chain_walk(prev, head_ptrs, max_matches)
    return jnp.sum(rows >= 0, axis=1)
