"""core — the paper's primary contribution: the Indexed DataFrame.

Layout:
  pointers.py   packed row pointers (paper's dense 64-bit ptr, TPU int32 form)
  hashing.py    partition + bucket hashes (routing vs placement)
  hashindex.py  dense bucketized hash index (cTrie replacement): bulk build,
                probe, backward-pointer chain walk
  schema.py     fixed-width schemas, row-wise + columnar codecs
  snapshot.py   Snapshot: the stored read-optimized pytree form (ragged
                probe planes + flat prev + optional flat data)
  table.py      IndexedTable: segments, MVCC appends, snapshots, compaction
  joins.py      indexed join/lookup + vanilla baselines (hash, sort-merge, scan)
  planner.py    Catalyst-analog rewrite rules -> physical operators
"""

from repro.core.schema import Schema, Column
from repro.core.snapshot import FlatBlock, Snapshot
from repro.core.table import (IndexedTable, FlatView, AppendQueue,
                              QueueOverflow, coalesce_deltas, create_index,
                              append, compact, empty_queue, enqueue,
                              flush_queue, queue_pending)
from repro.core.hashindex import HashIndex, build_index, probe, chain_walk
from repro.core.hashing import StringDictionary
from repro.core.partition import (PartitionSpec, PartitionedTable,
                                  append_partitioned, create_partitioned,
                                  drop_partition, join_partitioned,
                                  lookup_partitioned, retain)
from repro.core import joins, partition, planner

__all__ = [
    "Schema", "Column", "IndexedTable", "Snapshot", "FlatBlock", "FlatView",
    "AppendQueue", "PartitionSpec", "PartitionedTable", "QueueOverflow",
    "append_partitioned", "coalesce_deltas", "create_index",
    "create_partitioned", "drop_partition",
    "append", "compact", "empty_queue", "enqueue", "flush_queue",
    "join_partitioned", "lookup_partitioned",
    "queue_pending", "retain", "HashIndex", "StringDictionary",
    "build_index", "probe",
    "chain_walk", "joins", "partition", "planner",
]
