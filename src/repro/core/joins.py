"""Join and lookup operators: the indexed paths plus the vanilla baselines.

The paper's comparison (Fig 7/8, Table III) is *indexed join vs. what Spark
does*: per-query hash-table builds (BroadcastHash) or sort-merge.  We
implement all of them with identical output contracts so the benchmarks and
property tests compare like for like:

* ``indexed_join``     — paper §III-C: the indexed side is the pre-built
                         *build* side; probe rows are looked up against it.
* ``hash_join``        — baseline: builds a fresh transient index per call
                         (Spark's per-query hash-table build, amortized never).
* ``sort_merge_join``  — baseline: sort both sides + binary-search merge.
* ``scan_lookup``      — baseline point lookup: O(n) linear scan.
* ``indexed_lookup``   — paper's point lookup: O(1) probe + chain walk.

Output contract for joins: ``(result_cols, valid)`` where every probe row
yields ``max_matches`` slots (newest-first, padded) — static shapes for XLA.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashindex as hix
from repro.core.pointers import NULL_PTR
from repro.core.table import IndexedTable


# ---------------------------------------------------------------------------
# Input validation — ONE contract for every lookup/join entry point
# ---------------------------------------------------------------------------
#
# The facade (repro/frame.py), the local operators here, and the dist layer
# all enforce the same two checks through these helpers, so a bad call fails
# with the same ValueError no matter which surface it entered through.

def check_max_matches(max_matches: int):
    """Reject non-positive match-slot counts before any tracing happens."""
    if max_matches <= 0:
        raise ValueError(
            f"max_matches must be a positive match-slot count, "
            f"got {max_matches}")


def as_int64_keys(keys) -> jnp.ndarray:
    """Coerce ``keys`` to a jnp array and reject non-int64 dtypes."""
    keys = jnp.asarray(keys)
    if keys.dtype != jnp.int64:
        raise ValueError(
            f"query keys must be int64 (got {keys.dtype}); keys are int64 "
            f"at every API boundary — pre-hash string keys at ingest "
            f"(hashing.hash_string_host, DESIGN.md §9) and cast narrower "
            f"integer keys explicitly")
    return keys


# ---------------------------------------------------------------------------
# Indexed paths (the paper's contribution)
# ---------------------------------------------------------------------------

def indexed_lookup(table: IndexedTable, keys, *, max_matches: int,
                   names=None, fused: bool = True):
    """Point lookup: rows for each key, newest-first.  Returns
    (cols dict with shape [Q, max_matches], valid [Q, max_matches]).

    ``fused=True`` (default) runs the probe -> chain-walk -> gather pipeline
    in one pass over the table's stored Snapshot (DESIGN.md §3);
    ``fused=False`` keeps the segment-looped reference path for parity
    sweeps."""
    check_max_matches(max_matches)
    keys = as_int64_keys(keys)
    rids, _ = table.lookup(keys, max_matches, fused=fused)
    valid = rids != NULL_PTR
    cols = table.gather_rows(jnp.maximum(rids, 0), names=names, fused=fused)
    return cols, valid


def indexed_join(table: IndexedTable, probe_cols: dict, probe_key: str, *,
                 max_matches: int, names=None, fused: bool = True):
    """Equi-join: ``table`` (indexed) is the build side; ``probe_cols`` rows
    probe it locally (the distributed layer shuffles probes to the owning
    partition first; see dist/dtable.py).

    Returns (build_cols [Q, M], probe_cols broadcast [Q, M], valid [Q, M]).
    """
    keys = jnp.asarray(probe_cols[probe_key], jnp.int64)
    build_cols, valid = indexed_lookup(table, keys, max_matches=max_matches,
                                       names=names, fused=fused)
    m = valid.shape[1]
    probe_b = {k: jnp.broadcast_to(v[:, None], (v.shape[0], m))
               for k, v in probe_cols.items()}
    return build_cols, probe_b, valid


# ---------------------------------------------------------------------------
# Vanilla baselines (what Spark does per query)
# ---------------------------------------------------------------------------

def hash_join(build_cols: dict, build_key: str, probe_cols: dict,
              probe_key: str, *, max_matches: int,
              num_buckets: int | None = None):
    """Per-call hash join: builds the hash table *inside* the call, exactly
    the repeated work the paper's Fig 1 flame graph shows for vanilla Spark.

    With ``num_buckets`` given the build is single-shot (jit-traceable,
    used by the benchmarks); otherwise the host-coordinated
    overflow-doubling retry runs (exact, used by tests).
    """
    bkeys = jnp.asarray(build_cols[build_key], jnp.int64)
    n = bkeys.shape[0]
    rids = jnp.arange(n, dtype=jnp.int32)
    if num_buckets is not None:
        valid = jnp.ones((n,), bool)
        bk, bp, prev_rows, prev_vals, _ = hix._build_arrays(
            bkeys, rids, valid, num_buckets, hix.DEFAULT_SLOTS)
        index = hix.HashIndex(bk, bp, num_buckets, hix.DEFAULT_SLOTS)
    else:
        index, prev_rows, prev_vals = hix.build_index(bkeys, rids)
    prev = jnp.full((n,), NULL_PTR, jnp.int32)
    prev = prev.at[prev_rows].set(prev_vals, mode="drop")

    qkeys = jnp.asarray(probe_cols[probe_key], jnp.int64)
    head = hix.probe(index, qkeys)
    rows, _ = hix.chain_walk(prev, head, max_matches)
    valid = rows != NULL_PTR
    safe = jnp.maximum(rows, 0)
    out_build = {k: jnp.asarray(v)[safe] for k, v in build_cols.items()}
    m = valid.shape[1]
    out_probe = {k: jnp.broadcast_to(jnp.asarray(v)[:, None],
                                     (v.shape[0], m))
                 for k, v in probe_cols.items()}
    return out_build, out_probe, valid


def sort_merge_join(build_cols: dict, build_key: str, probe_cols: dict,
                    probe_key: str, *, max_matches: int):
    """Sort both sides, binary-search each probe key into the sorted build
    side, emit up to ``max_matches`` matches (newest build rows first, to
    match the indexed contract)."""
    bkeys = jnp.asarray(build_cols[build_key], jnp.int64)
    n = bkeys.shape[0]
    order = jnp.lexsort((jnp.arange(n), bkeys))
    k_s = bkeys[order]
    qkeys = jnp.asarray(probe_cols[probe_key], jnp.int64)
    lo = jnp.searchsorted(k_s, qkeys, side="left")
    hi = jnp.searchsorted(k_s, qkeys, side="right")
    # newest-first: walk from hi-1 downward
    offs = jnp.arange(max_matches, dtype=jnp.int32)
    pos = (hi - 1)[:, None] - offs[None, :]
    valid = pos >= lo[:, None]
    safe = jnp.clip(pos, 0, n - 1)
    rows = order[safe]
    out_build = {k: jnp.asarray(v)[rows] for k, v in build_cols.items()}
    out_probe = {k: jnp.broadcast_to(jnp.asarray(v)[:, None],
                                     (v.shape[0], max_matches))
                 for k, v in probe_cols.items()}
    return out_build, out_probe, valid


def scan_lookup(table: IndexedTable, keys, *, max_matches: int, names=None):
    """O(n) linear-scan point lookup (Spark without index/partitioning).
    Same output contract as indexed_lookup."""
    all_keys, row_valid = table.scan_column(table.schema.key)
    q = jnp.asarray(keys, jnp.int64)
    eq = (all_keys[None, :] == q[:, None]) & row_valid[None, :]   # [Q, N]
    n = all_keys.shape[0]
    # newest-first top-k via sorting match positions descending
    pos = jnp.where(eq, jnp.arange(n, dtype=jnp.int32)[None, :],
                    jnp.int32(-1))
    topk = jax.lax.top_k(pos, max_matches)[0]                      # [Q, M]
    valid = topk >= 0
    cols = table.gather_rows(jnp.maximum(topk, 0), names=names)
    return cols, valid


# ---------------------------------------------------------------------------
# Simple relational reducers used by the planner + benchmarks
# ---------------------------------------------------------------------------

def _reduce_identity(dtype, op: str):
    """Dtype-preserving identity for min/max (no silent int->float cast)."""
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        return dtype.type(info.max if op == "min" else info.min)
    return dtype.type(jnp.inf if op == "min" else -jnp.inf)


def aggregate(values, valid, op: str):
    v = jnp.asarray(values)
    if op == "sum":
        return jnp.sum(jnp.where(valid, v, v.dtype.type(0)))
    if op == "count":
        return jnp.sum(valid)
    if op in ("min", "max"):
        red = jnp.min if op == "min" else jnp.max
        return red(jnp.where(valid, v, _reduce_identity(v.dtype, op)))
    if op == "mean":
        total = jnp.sum(jnp.where(valid, v, v.dtype.type(0)))
        return total / jnp.maximum(jnp.sum(valid), 1)
    raise ValueError(op)
