"""Minimal stand-in for the ``hypothesis`` API used by this repo's tests.

The property tests in tests/ use a small slice of hypothesis: ``@given`` with
``integers``/``lists``/``floats`` strategies and ``@settings(max_examples,
deadline)``.  Hermetic containers do not always ship hypothesis, and the
tier-1 suite must still collect and run there, so ``tests/conftest.py`` calls
:func:`install` when the real package is missing.  The fallback is a
deterministic sampler (seeded per test name) — no shrinking, no database,
just N drawn examples per test.  When real hypothesis is importable it always
wins; this module is never registered.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 100


class SearchStrategy:
    """A strategy is just a draw function over a ``random.Random``."""

    def __init__(self, draw_fn, label: str = "strategy"):
        self._draw_fn = draw_fn
        self._label = label

    def draw(self, rnd: random.Random):
        return self._draw_fn(rnd)

    def __repr__(self):
        return f"<fallback {self._label}>"


def integers(min_value=None, max_value=None) -> SearchStrategy:
    lo = -(2 ** 63) if min_value is None else int(min_value)
    hi = 2 ** 63 - 1 if max_value is None else int(max_value)

    def draw(rnd):
        r = rnd.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        if r < 0.25 and lo <= 0 <= hi:
            return 0
        return rnd.randint(lo, hi)

    return SearchStrategy(draw, f"integers({lo}, {hi})")


def floats(min_value=None, max_value=None, *, allow_nan=None,
           allow_infinity=None, width: int = 64) -> SearchStrategy:
    span = 3.0e38 if width == 32 else 1.0e308
    lo = -span if min_value is None else float(min_value)
    hi = span if max_value is None else float(max_value)

    def draw(rnd):
        r = rnd.random()
        if r < 0.08:
            val = 0.0
        elif r < 0.16:
            val = -0.0
        elif r < 0.30:
            val = rnd.uniform(-1.0, 1.0)
        else:
            val = rnd.uniform(lo / 2, hi / 2)
        return min(max(val, lo), hi)

    return SearchStrategy(draw, "floats")


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size=None) -> SearchStrategy:
    cap = max_size if max_size is not None else min_size + 20

    def draw(rnd):
        r = rnd.random()
        if r < 0.15:
            n = min_size
        elif r < 0.30:
            n = cap
        else:
            # Quantize sizes to powers of two: bounds the number of
            # distinct array shapes the suite produces, so jit'd code
            # under test retraces O(log cap) times instead of O(examples).
            n = rnd.randint(min_size, cap)
            if n > 0:
                n = min(cap, max(min_size, 1 << (n.bit_length() - 1)))
        return [elements.draw(rnd) for _ in range(n)]

    return SearchStrategy(draw, f"lists[{min_size}..{cap}]")


def text(alphabet=None, *, min_size: int = 0, max_size=None) -> SearchStrategy:
    """Unicode strings biased toward hashing edge cases: empty, ASCII,
    NUL bytes, multi-byte codepoints, and surrogate-free astral chars."""
    cap = max_size if max_size is not None else min_size + 20
    pool = (list(alphabet) if alphabet is not None else
            [chr(c) for c in range(0x20, 0x7F)]
            + ["\x00", "\x01", "é", "ß", "…", "中", "🦜", "߿", "￿"])

    def draw(rnd):
        r = rnd.random()
        n = min_size if r < 0.15 else (cap if r < 0.30
                                       else rnd.randint(min_size, cap))
        return "".join(rnd.choice(pool) for _ in range(n))

    return SearchStrategy(draw, f"text[{min_size}..{cap}]")


def sampled_from(options) -> SearchStrategy:
    options = list(options)
    return SearchStrategy(lambda rnd: rnd.choice(options), "sampled_from")


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.random() < 0.5, "booleans")


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rnd: value, "just")


def one_of(*strategies: SearchStrategy) -> SearchStrategy:
    opts = list(strategies)
    return SearchStrategy(lambda rnd: rnd.choice(opts).draw(rnd), "one_of")


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Decorator recording the example budget (deadline etc. are ignored)."""

    def decorate(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return decorate


def given(*strategies: SearchStrategy):
    """Run the test once per drawn example, seeded by the test's name."""

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples",
                                DEFAULT_MAX_EXAMPLES))
            rnd = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = [s.draw(rnd) for s in strategies]
                fn(*args, *drawn, **kwargs)

        # Hide the strategy-filled (rightmost) parameters from pytest so it
        # does not try to resolve them as fixtures.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        keep = params[:len(params) - len(strategies)]
        wrapper.__signature__ = sig.replace(parameters=keep)
        del wrapper.__wrapped__
        return wrapper

    return decorate


def install() -> None:
    """Register this module as ``hypothesis`` (+ ``hypothesis.strategies``)."""
    if "hypothesis" in sys.modules:  # real package (or prior install) wins
        return
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.SearchStrategy = SearchStrategy
    hyp.__version__ = "0.0-repro-fallback"
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "lists", "text", "sampled_from",
                 "booleans", "just", "one_of"):
        setattr(st, name, globals()[name])
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
